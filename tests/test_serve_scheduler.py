"""Continuous-batching serve scheduler: admission, KV-block budget
preemption/requeue, exactly-once per-request streams, rpc integration
(interleaved generate_stream pumps, metrics gauges, phase spans)."""
import numpy as np
import pytest

from _hypothesis_support import given, settings, st
from repro.serve.scheduler import (CANCELLED, FINISHED, PREEMPTED,
                                   Request, ServeScheduler,
                                   blocks_per_seq)


class FakeEngine:
    """Deterministic stand-in for ServeEngine's scheduler ops: token t
    of a request is a pure function of its prompt and t, and rebuild
    recomputes exactly the state decode left — so the scheduler's
    exactly-once / byte-identity contracts are testable without jax."""

    class _Cfg:
        max_seq = 64
        max_new_tokens = 4

    def __init__(self):
        self.cfg = self._Cfg()
        self.prefills = self.decodes = self.rebuilds = 0

    def _tok(self, req, t):
        base = int(req.prompts.sum()) % 997
        return np.full(req.rows, base + 7 * t, dtype=np.int32)

    def scheduler_prefill(self, req):
        self.prefills += 1
        req.runtime = ("state", 0)
        return self._tok(req, 0)

    def scheduler_decode(self, req):
        self.decodes += 1
        assert req.runtime == ("state", len(req.tokens) - 1), \
            "decode must resume from the rebuilt state"
        req.runtime = ("state", len(req.tokens))
        return self._tok(req, len(req.tokens))

    def scheduler_rebuild(self, req):
        self.rebuilds += 1
        assert req.runtime is None, "rebuild implies dropped state"
        req.runtime = ("state", len(req.tokens) - 1)


def _expected(req):
    base = int(req.prompts.sum()) % 997
    return [np.full(req.rows, base + 7 * t, dtype=np.int32)
            for t in range(req.max_new_tokens)]


def _prompts(rows, plen, fill):
    return np.full((rows, plen), fill, dtype=np.int32)


# ---------------------------------------------------------------------------
# block accounting
# ---------------------------------------------------------------------------

def test_blocks_per_seq():
    assert blocks_per_seq(1, 0) == 1
    assert blocks_per_seq(16, 0) == 1
    assert blocks_per_seq(16, 1) == 2
    assert blocks_per_seq(8, 4, block_size=4) == 3
    assert blocks_per_seq(8, 0, block_size=1) == 8
    prev = 0
    for g in range(40):          # monotone, never shrinks with growth
        cur = blocks_per_seq(5, g, block_size=4)
        assert cur >= prev
        prev = cur


def test_request_blocks_scale_with_rows():
    req = Request(1, _prompts(3, 8, 1), 4)
    assert req.blocks(block_size=4) == 3 * blocks_per_seq(8, 0,
                                                          block_size=4)
    req.tokens.append(np.zeros(3, np.int32))
    assert req.blocks(block_size=1, extra=2) == 3 * (8 + 1 + 2)


# ---------------------------------------------------------------------------
# scheduler core (fake engine)
# ---------------------------------------------------------------------------

def test_single_request_runs_to_completion():
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=2)
    req = sched.submit(_prompts(2, 8, 3), 4)
    out = sched.run(req)
    exp = np.stack(_expected(req), axis=1)
    assert np.array_equal(out, exp)
    assert req.finished and req.runtime is None
    assert eng.prefills == 1 and eng.decodes == 3
    assert sched.counters["finished"] == 1
    assert not sched.running and not sched.waiting


def test_submit_rejects_over_max_seq():
    sched = ServeScheduler(FakeEngine())
    with pytest.raises(AssertionError):
        sched.submit(_prompts(1, 62, 1), 4)      # 62 + 4 > max_seq 64


def test_max_batch_caps_concurrency_and_third_joins_midflight():
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=2)
    reqs = [sched.submit(_prompts(1, 4, i + 1), 4) for i in range(3)]
    sched.step()
    assert len(sched.running) == 2 and len(sched.waiting) == 1
    outs = [sched.run(r) for r in reqs]
    for req, out in zip(reqs, outs):
        assert np.array_equal(out, np.stack(_expected(req), axis=1))
    assert sched.counters["peak_running"] == 2
    assert sched.counters["finished"] == 3
    # the third request joined the shared loop, not a fresh batch
    assert sched.counters["admitted"] == 3


def test_kv_budget_preempts_and_requeues_until_all_finish():
    """The acceptance shape: a budget that fits both requests at
    admission but not through decode growth — the newest is preempted
    (state dropped), requeued, rebuilt, and still completes with
    exactly the tokens it would have produced alone."""
    eng = FakeEngine()
    # per-seq blocks at block_size=1: prompt 8 + generated; two
    # requests outgrow 21 blocks after their first decode step
    sched = ServeScheduler(eng, max_batch=4, kv_blocks=21, block_size=1)
    r1 = sched.submit(_prompts(1, 8, 1), 4)
    r2 = sched.submit(_prompts(1, 8, 2), 4)
    while not (r1.finished and r2.finished):
        sched.step()
    for req in (r1, r2):
        got = np.stack(req.tokens, axis=1)
        assert np.array_equal(got, np.stack(_expected(req), axis=1))
        assert len(req.tokens) == 4          # exactly once, no dupes
    assert sched.counters["preempted"] >= 1
    assert sched.counters["requeued"] == sched.counters["preempted"]
    assert eng.rebuilds >= 1
    assert sched.used_blocks() == 0 and not sched.waiting


def test_lone_over_budget_request_still_runs():
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=2, kv_blocks=2, block_size=1)
    req = sched.submit(_prompts(1, 8, 5), 3)    # needs >> 2 blocks
    out = sched.run(req)
    assert out.shape == (1, 3)
    assert sched.counters["preempted"] == 0     # never self-preempts


def test_stream_tokens_exactly_once_across_preemption():
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=4, kv_blocks=21, block_size=1)
    r1 = sched.submit(_prompts(1, 8, 1), 4)
    r2 = sched.submit(_prompts(1, 8, 2), 4)
    s1, s2 = sched.stream_tokens(r1), sched.stream_tokens(r2)
    got1, got2 = [], []
    done1 = done2 = False
    while not (done1 and done2):     # alternate consumers
        if not done1:
            tok = next(s1, None)
            done1 = tok is None
            if tok is not None:
                got1.append(tok)
        if not done2:
            tok = next(s2, None)
            done2 = tok is None
            if tok is not None:
                got2.append(tok)
    assert sched.counters["preempted"] >= 1
    for req, got in ((r1, got1), (r2, got2)):
        assert len(got) == 4
        for a, b in zip(got, _expected(req)):
            assert np.array_equal(a, b)


def test_closing_stream_cancels_request():
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=2)
    req = sched.submit(_prompts(1, 4, 1), 4)
    stream = sched.stream_tokens(req)
    next(stream)
    stream.close()                   # consumer gone mid-decode
    assert req.state == CANCELLED and req.runtime is None
    assert not sched.running and sched.counters["cancelled"] == 1
    # a cancelled request never blocks later traffic
    other = sched.submit(_prompts(1, 4, 2), 2)
    assert np.array_equal(sched.run(other),
                          np.stack(_expected(other), axis=1))


def test_stats_shape():
    sched = ServeScheduler(FakeEngine(), max_batch=2, kv_blocks=9)
    st_ = sched.stats()
    for key in ("submitted", "admitted", "finished", "preempted",
                "requeued", "cancelled", "steps", "peak_running",
                "peak_waiting", "running", "waiting", "used_blocks",
                "kv_blocks"):
        assert key in st_, key
    assert st_["kv_blocks"] == 9


@given(data=st.data())
@settings(max_examples=50, deadline=None)
def test_arrival_and_consumption_order_never_change_tokens(data):
    """The tentpole property: whatever the arrival schedule, the
    consumer interleaving, the batch cap, or the KV budget (with its
    preemptions), every request's stream is exactly its solo token
    sequence — continuous batching never leaks one request's schedule
    into another's output."""
    n = data.draw(st.integers(1, 4), label="n_requests")
    specs = [(data.draw(st.integers(1, 3)), data.draw(st.integers(1, 6)),
              data.draw(st.integers(1, 5))) for _ in range(n)]
    eng = FakeEngine()
    sched = ServeScheduler(
        eng,
        max_batch=data.draw(st.integers(1, 3), label="max_batch"),
        kv_blocks=data.draw(st.one_of(st.none(), st.integers(6, 60)),
                            label="kv_blocks"),
        block_size=data.draw(st.integers(1, 4), label="block_size"))
    pending = list(range(n))
    active, results, reqs = {}, {}, {}
    while pending or active:
        submit = pending and (not active
                              or data.draw(st.booleans(), label="submit"))
        if submit:
            i = pending.pop(0)
            rows, plen, mnt = specs[i]
            req = sched.submit(_prompts(rows, plen, i + 1), mnt)
            reqs[i] = req
            active[i] = sched.stream_tokens(req)
            results[i] = []
        else:
            i = data.draw(st.sampled_from(sorted(active)), label="pull")
            tok = next(active[i], None)
            if tok is None:
                del active[i]
            else:
                results[i].append(tok)
    for i, req in reqs.items():
        exp = _expected(req)
        assert len(results[i]) == len(exp)
        for a, b in zip(results[i], exp):
            assert np.array_equal(a, b)
    assert not sched.running and not sched.waiting
    assert sched.counters["finished"] == n
    assert sched.counters["requeued"] == sched.counters["preempted"]


# ---------------------------------------------------------------------------
# over the rpc fabric (real engine, reduced config)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def eng():
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.parallel import NO_MESH
    from repro.serve.engine import ServeConfig, ServeEngine
    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return ServeEngine(NO_MESH, cfg, params,
                       ServeConfig(max_seq=64, max_new_tokens=4))


def _rng_prompts(eng_, rows, plen, seed):
    vocab = eng_.acfg.model.vocab_size
    return np.random.default_rng(seed).integers(
        0, vocab, (rows, plen), dtype=np.int32)


def test_staggered_join_matches_solo_generate(eng):
    """A request submitted while another is mid-decode joins the shared
    step; both streams stay byte-identical to their solo runs."""
    sched = eng.make_scheduler(max_batch=4)
    p1 = _rng_prompts(eng, 2, 8, 1)
    p2 = _rng_prompts(eng, 2, 8, 2)
    solo1, solo2 = eng.generate(p1), eng.generate(p2)
    r1 = sched.submit(p1)
    s1 = sched.stream_tokens(r1)
    got1 = [next(s1), next(s1)]          # two tokens decoded already
    r2 = sched.submit(p2)                # late joiner
    s2 = sched.stream_tokens(r2)
    got2 = list(s2)
    got1 += list(s1)
    assert np.array_equal(np.stack(got1, axis=1), solo1)
    assert np.array_equal(np.stack(got2, axis=1), solo2)
    assert sched.counters["peak_running"] == 2
    assert sched.counters["admitted"] == 2 and not sched.running


def test_concurrent_streams_over_rpc_interleave_and_match(eng):
    """Two generate_stream calls on one endpoint: chunks come from the
    shared decode step (pumped, so queue depth sees both in flight) and
    each client's reassembled block equals the solo run."""
    from repro import rpc as rpclib
    from repro.serve.engine import decode_token_chunk, serve_stub
    metrics = rpclib.MetricsInterceptor()
    fab = rpclib.RpcFabric(rpclib.make_transport("loopback", 2),
                           server_interceptors=[metrics])
    eng.attach(fab.add_server(0), max_batch=4)
    stub = serve_stub(fab.channel(1, 0))
    p1 = _rng_prompts(eng, 2, 8, 3)
    p2 = _rng_prompts(eng, 2, 8, 4)
    h1 = stub.generate_stream((p1, 0))
    h2 = stub.generate_stream((p2, 0))
    fab.flush()
    out1 = np.stack([decode_token_chunk(c) for c in h1.result()], axis=1)
    out2 = np.stack([decode_token_chunk(c) for c in h2.result()], axis=1)
    assert np.array_equal(out1, eng.generate(p1))
    assert np.array_equal(out2, eng.generate(p2))
    snap = metrics.snapshot(gauges=True)
    # both calls were open at once server-side...
    assert snap["server:Serve/generate_stream"]["queue_peak"] >= 2
    # ...and the endpoint scheduler really ran them as one batch
    sched_stats = snap["serve:scheduler@0"]
    assert sched_stats["peak_running"] == 2
    assert sched_stats["finished"] == 2


def test_kv_exhaustion_over_rpc_preempts_requeues_and_traces(eng):
    """KV budget for one-and-a-bit sequences, two streaming calls: the
    newest is preempted + requeued (visible in the metrics gauges) yet
    both clients get byte-identical results, and the scheduler's
    waiting/prefill/decode/preempted phases land in the Chrome trace."""
    from repro import rpc as rpclib
    from repro.serve.engine import decode_token_chunk, serve_stub
    metrics = rpclib.MetricsInterceptor()
    tracer = rpclib.Tracer()
    fab = rpclib.RpcFabric(rpclib.make_transport("loopback", 2),
                           server_interceptors=[metrics], tracer=tracer)
    eng.attach(fab.add_server(0), max_batch=4, kv_blocks=21,
               block_size=1)
    stub = serve_stub(fab.channel(1, 0))
    p1 = _rng_prompts(eng, 1, 8, 5)
    p2 = _rng_prompts(eng, 1, 8, 6)
    h1 = stub.generate_stream((p1, 0))
    h2 = stub.generate_stream((p2, 0))
    fab.flush()
    out1 = np.stack([decode_token_chunk(c) for c in h1.result()], axis=1)
    out2 = np.stack([decode_token_chunk(c) for c in h2.result()], axis=1)
    assert np.array_equal(out1, eng.generate(p1))
    assert np.array_equal(out2, eng.generate(p2))
    gauges = metrics.snapshot(gauges=True)["serve:scheduler@0"]
    assert gauges["preempted"] >= 1
    assert gauges["requeued"] == gauges["preempted"]
    assert gauges["finished"] == 2
    names = {e["name"] for e in tracer.chrome_events()}
    for phase in ("waiting", "prefill", "decode", "preempted"):
        assert phase in names, (phase, sorted(names))


def test_unary_over_rpc_shares_the_endpoint_scheduler(eng):
    from repro import rpc as rpclib
    from repro.serve.engine import serve_stub
    fab = rpclib.RpcFabric(rpclib.make_transport("loopback", 2))
    sched = eng.attach(fab.add_server(0), max_batch=4)
    stub = serve_stub(fab.channel(1, 0))
    p = _rng_prompts(eng, 2, 8, 7)
    out = stub.generate((p, 0)).result()
    assert np.array_equal(out, eng.generate(p))
    assert sched.counters["finished"] == 1


def test_scheduler_least_loaded_steers_to_idle_shard(eng):
    """The scheduler-aware dispatch policy reads each endpoint's live
    scheduler gauge (running + waiting), so a shard decoding requests
    another client submitted loses ties the client's own outstanding
    book would never see."""
    from repro import rpc as rpclib
    from repro.serve.engine import ShardedServeStub
    metrics = rpclib.MetricsInterceptor()
    fab = rpclib.RpcFabric(rpclib.make_transport("loopback", 3),
                           server_interceptors=[metrics])
    sched0 = eng.attach(fab.add_server(0), max_batch=1)
    eng.attach(fab.add_server(1), max_batch=1)
    stub = ShardedServeStub(fab, 2, (0, 1),
                            policy="scheduler_least_loaded")
    assert stub._pick() == 0                     # all idle: first shard
    # another client's work lands in shard 0's scheduler: one request
    # decoding, one queued behind max_batch=1 -> load 2
    r1 = sched0.submit(_rng_prompts(eng, 1, 8, 11))
    r2 = sched0.submit(_rng_prompts(eng, 1, 8, 12))
    sched0.step()
    assert stub._shard_queue_depth(0) == 2
    assert stub._shard_queue_depth(1) == 0
    p = _rng_prompts(eng, 1, 8, 13)
    h = stub.generate(p, 0)
    assert len(stub._inflight[1]) == 1           # steered off shard 0
    fab.flush()
    assert np.array_equal(h.result(), eng.generate(p))
    for r in (r1, r2):
        assert np.array_equal(sched0.run(r),
                              eng.generate(r.prompts))


# ---------------------------------------------------------------------------
# admission policy (sjf)
# ---------------------------------------------------------------------------

class _Clock:
    """Minimal server stand-in: gives the scheduler a controllable
    clock (bind() only reads .clock/.tracer/.endpoint)."""
    endpoint = 0
    tracer = None

    def __init__(self):
        self.t = 0.0

    def clock(self):
        return self.t


def _admission_order(sched, reqs):
    order, seen = [], set()
    while not all(r.finished for r in reqs):
        sched.step()
        for r in sched.running:
            if r.id not in seen:
                seen.add(r.id)
                order.append(r.id)
    return order


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown scheduler policy"):
        ServeScheduler(FakeEngine(), policy="lifo")


def test_sjf_admits_shortest_first_with_fifo_tiebreak():
    # one slot: admission order is fully observable. Two plen-2
    # requests tie -> earlier submit id wins; the plen-8 goes last.
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=1, policy="sjf")
    long_ = sched.submit(_prompts(1, 8, 3))
    a = sched.submit(_prompts(1, 2, 5))
    b = sched.submit(_prompts(1, 2, 9))
    assert _admission_order(sched, [long_, a, b]) \
        == [a.id, b.id, long_.id]
    assert sched.stats()["policy"] == "sjf"
    # fifo baseline: same prompts admit in arrival order
    fifo = ServeScheduler(FakeEngine(), max_batch=1, policy="fifo")
    r1 = fifo.submit(_prompts(1, 8, 3))
    r2 = fifo.submit(_prompts(1, 2, 5))
    r3 = fifo.submit(_prompts(1, 2, 9))
    assert _admission_order(fifo, [r1, r2, r3]) \
        == [r1.id, r2.id, r3.id]


def test_sjf_preempted_resumes_before_shorter_fresh_request():
    # block_size=1 makes the budget arithmetic transparent: two plen-4
    # requests outgrow kv_blocks=12 at generated=2, evicting the
    # newest (r2). A fresh plen-1 request then joins the queue — but
    # r2's recompute debt wins: it resumes (rebuild, not prefill)
    # ahead of the shorter newcomer, and every stream still delivers
    # its exact token sequence.
    eng = FakeEngine()
    sched = ServeScheduler(eng, max_batch=2, kv_blocks=12,
                           block_size=1, policy="sjf")
    r1 = sched.submit(_prompts(1, 4, 1))
    r2 = sched.submit(_prompts(1, 4, 2))
    for _ in range(3):
        sched.step()
    assert r2.state == PREEMPTED and r2 in sched.waiting
    short = sched.submit(_prompts(1, 1, 7))
    while not r2.state == "running":
        sched.step()
    assert sched.running[0] is r2        # resumed ahead of `short`
    assert eng.rebuilds == 1
    for r in (r1, r2, short):
        sched.run(r)
        assert all(np.array_equal(t, e)
                   for t, e in zip(r.tokens, _expected(r)))


def test_sjf_starvation_age_restores_fifo_priority():
    # a long prompt parked past starvation_age_s regains strict FIFO
    # priority over fresh short prompts
    clock = _Clock()
    sched = ServeScheduler(FakeEngine(), max_batch=1, policy="sjf",
                           starvation_age_s=1.0).bind(clock)
    long_ = sched.submit(_prompts(1, 8, 3))
    short1 = sched.submit(_prompts(1, 2, 5))
    while not short1.finished:           # sjf favors short1 first
        sched.step()
    assert not long_.finished
    clock.t = 2.0                        # long_ now starved (age 2.0)
    short2 = sched.submit(_prompts(1, 2, 9))
    sched.step()
    assert sched.running[0] is long_     # fifo escape hatch fired
    assert not short2.finished
    # control: without the escape hatch, short2 would have won
    ctrl = ServeScheduler(FakeEngine(), max_batch=1,
                          policy="sjf").bind(_Clock())
    c_long = ctrl.submit(_prompts(1, 8, 3))
    c_short = ctrl.submit(_prompts(1, 2, 5))
    ctrl.step()
    assert ctrl.running[0] is c_short and c_long in ctrl.waiting
