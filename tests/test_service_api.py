"""The gRPC-style service/stub API: ServiceDef/Stub binding, interceptor
chains (ordering, metrics, deadline, retry), deadline enforcement on
stalled streams, duplicate-registration errors, incast fetch asymmetry,
the scaling sweep axes, and the deprecated-shim delegation contract."""
import json
import pathlib
import re

import numpy as np
import pytest

from repro import rpc
from repro.configs.tfgrpc_bench import BenchConfig
from repro.core.netmodel import NETWORKS
from repro.core.payload import PayloadSpec, scale_sizes


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


ECHO = rpc.ServiceDef("Echo", (
    rpc.MethodSpec("inc", rpc.UNARY),
    rpc.MethodSpec("concat", rpc.CLIENT_STREAM),
    rpc.MethodSpec("rng", rpc.SERVER_STREAM),
    rpc.MethodSpec("mirror", rpc.BIDI),
))

ECHO_HANDLERS = {
    "inc": lambda req: [(req[0] + 1).astype(np.uint8)],
    "concat": lambda req: [np.concatenate(req)],
    "rng": lambda req: [[np.full(8, i, np.uint8)] for i in range(3)],
    "mirror": lambda c, end: [c] if c else None,
}


def _echo_fabric(**kw):
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2), **kw)
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    return fab


# ---------------------------------------------------------------------------
# ServiceDef / Stub
# ---------------------------------------------------------------------------

def test_stub_all_four_kinds():
    fab = _echo_fabric()
    stub = fab.stub(ECHO, 0, 1)
    u = stub.inc([np.zeros(4, np.uint8)])
    cs = stub.concat([[np.full(2, 1, np.uint8)], [np.full(2, 2, np.uint8)]])
    ss = stub.rng([np.zeros(1, np.uint8)])
    bd = stub.mirror([[np.full(4, 7, np.uint8)]])
    fab.flush()
    assert np.array_equal(u.result()[0], np.ones(4, np.uint8))
    assert np.array_equal(cs.result()[0], np.array([1, 1, 2, 2],
                                                   np.uint8))
    assert [int(c[0][0]) for c in ss.chunk_bufs()] == [0, 1, 2]
    assert [int(c[0][0]) for c in bd.chunk_bufs()] == [7]


def test_unary_call_result_drives_flush():
    """UnaryCall.result() flushes the fabric itself when needed."""
    fab = _echo_fabric()
    call = fab.stub(ECHO, 0, 1).inc([np.zeros(4, np.uint8)])
    assert not call.done
    assert np.array_equal(call.result()[0], np.ones(4, np.uint8))


def test_stub_method_kind_mismatch_errors():
    fab = _echo_fabric()
    stub = fab.stub(ECHO, 0, 1)
    with pytest.raises(ValueError, match="method-kind mismatch"):
        stub.inc.server_stream([np.zeros(1, np.uint8)])
    with pytest.raises(ValueError, match="method-kind mismatch"):
        stub.rng.unary([np.zeros(1, np.uint8)])
    with pytest.raises(ValueError, match="method-kind mismatch"):
        stub.mirror.client_stream([[np.zeros(1, np.uint8)]])
    with pytest.raises(ValueError, match="method-kind mismatch"):
        stub.concat.bidi()
    with pytest.raises(AttributeError, match="no method 'nosuch'"):
        stub.nosuch


def test_stub_attribute_probe_on_unpopulated_instance():
    """__getattr__ must degrade to AttributeError (not recurse) when
    the instance dict is empty — copy/pickle protocols probe attributes
    on instances created via object.__new__."""
    from repro.rpc.service import Stub
    bare = object.__new__(Stub)
    with pytest.raises(AttributeError):
        getattr(bare, "__setstate__")
    assert getattr(bare, "__setstate__", None) is None


def test_sweep_benchmark_cross_stream_chunks_drops_fully_connected(
        tmp_path):
    """benchmark x stream_chunks crosses only the streaming families —
    fully_connected ignores the chunk count and would emit identical
    rows dressed up as a curve."""
    from repro.launch import bench_comm
    out = tmp_path / "rows.json"
    bench_comm.main(["--sweep", "benchmark,stream_chunks",
                     "--transport", "simulated", "--network", "eth40g",
                     "--num-workers", "4", "--json", str(out)])
    rows = json.loads(out.read_text())["rows"]
    assert {r["benchmark"] for r in rows} == {"ring", "incast"}
    assert len(rows) == 2 * 4


def test_service_def_validation():
    with pytest.raises(ValueError, match="duplicate method"):
        rpc.ServiceDef("S", (rpc.MethodSpec("m"), rpc.MethodSpec("m")))
    with pytest.raises(ValueError, match="unknown kind"):
        rpc.MethodSpec("m", kind="datagram")
    with pytest.raises(ValueError, match="no method"):
        ECHO.spec("nosuch")


def test_duplicate_registration_raises():
    """Re-registering a method (or re-adding a service) is an error,
    not silent last-write-wins."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register("m", lambda req: req)
    with pytest.raises(ValueError, match="already registered"):
        srv.register("m", lambda req: None)
    srv.add_service(ECHO, ECHO_HANDLERS)
    with pytest.raises(ValueError, match="already added"):
        srv.add_service(ECHO, ECHO_HANDLERS)


def test_add_service_is_atomic_on_missing_handler():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    with pytest.raises(ValueError, match="missing"):
        srv.add_service(ECHO, {"inc": lambda req: req})
    # nothing half-registered: the full service binds cleanly after
    srv.add_service(ECHO, ECHO_HANDLERS)


def test_stub_is_cached_per_channel():
    fab = _echo_fabric()
    assert fab.stub(ECHO, 0, 1) is fab.stub(ECHO, 0, 1)
    assert fab.stub(ECHO, 0, 1) is not fab.stub(ECHO, 0, 1,
                                                serialized=True)
    # keyed by service identity: a different live ServiceDef sharing
    # the name must not alias into the cached stub
    echo2 = rpc.ServiceDef("Echo", (rpc.MethodSpec("other", rpc.UNARY),))
    assert fab.stub(echo2, 0, 1).other.spec.name == "other"


def test_add_service_atomic_on_wire_name_collision():
    """A method already registered through the deprecated direct API
    must fail add_service BEFORE any of the service's methods bind."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.register(ECHO.full_name("rng"), lambda req: [])   # squatter
    with pytest.raises(ValueError, match="already registered"):
        srv.add_service(ECHO, ECHO_HANDLERS)
    # nothing half-bound: 'inc' (earlier in the def) was not registered
    c = fab.stub(ECHO, 0, 1).inc([np.zeros(4, np.uint8)])
    fab.flush()
    with pytest.raises(rpc.RpcError, match="unimplemented"):
        c.reply_bufs()


def test_server_stream_generator_fault_becomes_rpc_error():
    """Lazy server-stream handlers (generators) whose errors surface
    mid-iteration must produce an RPC error reply, not crash flush."""
    def gen_handler(req):
        def g():
            yield [np.zeros(4, np.uint8)]
            raise ValueError("mid-stream boom")
        return g()

    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).register_server_stream("g", gen_handler)
    h = fab.channel(0, 1).server_stream("g", [np.zeros(1, np.uint8)])
    fab.flush()                       # must not raise
    with pytest.raises(rpc.RpcError, match="mid-stream boom"):
        h.chunk_bufs()


def test_deadline_cancel_drops_pending_frames_and_refunds_credits():
    """A cancelled stream's already-admitted frames are dropped from
    the next flight with their credits refunded — a chunk delivered
    after the cancel would re-create server stream state that no END
    will ever clean up."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1000, window_msgs=8)
    srv = fab.add_server(1)
    srv.add_service(ECHO, ECHO_HANDLERS)
    ch = fab.channel(0, 1)
    # chunk 0 is admitted at submit; 1..2 backlog; deadline pre-expired
    c = fab.stub(ECHO, 0, 1).concat.client_stream(
        [[np.full(900, i, np.uint8)] for i in range(3)],
        deadline_s=-1.0)
    fab.flush()
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        c.result()
    assert srv._streams == {}         # nothing delivered, nothing leaked
    assert ch.window.bytes_avail == 1000 and ch.backlogged == 0


def test_one_way_stream_completes_on_end_not_first_chunk():
    """The 'sent' completion of a one-way stream fires when the END
    chunk is consumed, so the call context (deadline, metrics) covers
    the whole stream."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=512, window_msgs=8)
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    c = fab.stub(ECHO, 0, 1).concat.client_stream(
        [[np.full(400, i, np.uint8)] for i in range(3)], one_way=True)
    fab.flush()
    kinds = [e.kind for e in fab.cq.drain() if e.tag == c.call_id]
    assert kinds.count("sent") == 1
    assert kinds[:3] == ["received"] * 3      # all chunks consumed first
    assert kinds[-1] == "sent"


def test_deadline_cancel_cleans_server_stream_state():
    """Cancelling a partially-delivered stream must drop the server's
    buffered chunks / bidi seq state — the END that would clean them up
    will never arrive."""
    import time as _t

    from repro.rpc import framing
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    srv = fab.add_server(1)
    srv.add_service(ECHO, ECHO_HANDLERS)
    ch = fab.channel(0, 1)
    # a client-stream chunk with no END, under a short deadline
    cid = fab.next_call_id()
    frame = framing.stream_chunk(cid, ECHO.full_name("concat"),
                                 [np.zeros(8, np.uint8)], seq=0)
    c = fab.submit(ch, frame, ECHO.full_name("concat"),
                   kind=rpc.CLIENT_STREAM, deadline_s=0.01)
    fab.flush()
    assert cid in srv._streams        # partial stream buffered
    _t.sleep(0.02)
    fab.flush()                       # deadline scan cancels the call
    assert srv._streams == {}
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        c.reply_bufs()
    # bidi half: per-call seq state is cleaned the same way
    h = fab.stub(ECHO, 0, 1).mirror(deadline_s=0.01)
    h.send([np.zeros(4, np.uint8)])
    fab.flush()
    assert h.call_id in srv._bidi_seq
    _t.sleep(0.02)
    fab.flush()
    assert h.done and srv._bidi_seq == {}


# ---------------------------------------------------------------------------
# interceptors
# ---------------------------------------------------------------------------

class _Rec(rpc.ClientInterceptor, rpc.ServerInterceptor):
    def __init__(self, name, log):
        self.name, self.log = name, log

    def on_start(self, ctx):
        self.log.append(f"{self.name}.start")

    def on_complete(self, ctx, ev):
        self.log.append(f"{self.name}.complete")

    def on_receive(self, ctx):
        self.log.append(f"{self.name}.recv")

    def on_done(self, ctx, ok, error=None):
        self.log.append(f"{self.name}.done")


def test_interceptor_ordering_client_wire_server_and_back():
    """The chain nests gRPC-style: client start outer->inner, server
    receive outer->inner, server done inner->outer, client complete
    inner->outer."""
    log = []
    a, b = _Rec("A", log), _Rec("B", log)
    s1, s2 = _Rec("S1", log), _Rec("S2", log)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        client_interceptors=[a, b],
                        server_interceptors=[s1, s2])
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    fab.stub(ECHO, 0, 1).inc([np.zeros(4, np.uint8)])
    fab.flush()
    assert log == ["A.start", "B.start",            # client, outer->inner
                   "S1.recv", "S2.recv",            # wire -> server
                   "S2.done", "S1.done",            # server unwind
                   "B.complete", "A.complete"]      # client unwind


def test_metrics_interceptor_counts_and_percentiles():
    m = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        client_interceptors=[m])
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    stub = fab.stub(ECHO, 0, 1)
    for _ in range(4):
        stub.inc([np.zeros(4, np.uint8)])
    stub.rng([np.zeros(1, np.uint8)])
    fab.flush()
    snap = m.snapshot()
    inc = snap["Echo/inc"]
    assert inc["calls"] == 4 and inc["ok"] == 4 and inc["errors"] == 0
    assert inc["latency_us"]["p50"] > 0
    assert inc["latency_us"]["p95"] >= inc["latency_us"]["p50"]
    assert snap["Echo/rng"]["chunks"] == 3


def test_metrics_on_modeled_clock():
    """On a simulated transport latencies come from the modeled clock,
    so they are deterministic and equal the flight pricing."""
    m = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(rpc.SimulatedTransport(2, NETWORKS["eth40g"]),
                        client_interceptors=[m])
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    fab.stub(ECHO, 0, 1).inc(None, sizes=[1 << 20])
    rep = fab.flush()
    lat = m.snapshot()["Echo/inc"]["latency_us"]
    assert lat["p50"] == pytest.approx(rep.elapsed_s * 1e6)


def test_server_interceptor_sees_handler_fault():
    log = []
    s = _Rec("S", log)

    done = []

    class Catch(rpc.ServerInterceptor):
        def on_done(self, ctx, ok, error=None):
            done.append((ctx.method, ok, error))

    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        server_interceptors=[s, Catch()])

    def boom(req):
        raise ValueError("nope")
    fab.add_server(1).register("boom", boom)
    c = fab.channel(0, 1).call("boom", [np.zeros(1, np.uint8)])
    fab.flush()
    with pytest.raises(rpc.RpcError, match="nope"):
        c.reply_bufs()
    assert done == [("boom", False, "nope")]


def test_retry_interceptor_on_transient():
    seen = {"n": 0}

    def flaky(req):
        seen["n"] += 1
        if seen["n"] < 3:
            raise rpc.TransientError("warming up")
        return req

    retry = rpc.RetryInterceptor(max_attempts=5)
    metrics = rpc.MetricsInterceptor()
    # metrics OUTER to retry: sees only the final outcome
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        client_interceptors=[metrics, retry])
    fab.add_server(1).register("flaky", flaky)
    c = fab.channel(0, 1).call("flaky", [np.arange(4, dtype=np.uint8)])
    fab.flush()
    assert seen["n"] == 3 and retry.retries == 2
    assert np.array_equal(c.reply_bufs()[0],
                          np.arange(4, dtype=np.uint8))
    rec = metrics.snapshot()["flaky"]
    assert rec["errors"] == 0 and rec["ok"] == 1
    assert rec["retries"] == 2          # visible as retry events


def test_retry_gives_up_after_max_attempts():
    def always(req):
        raise rpc.TransientError("still down")
    fab = rpc.RpcFabric(
        rpc.LoopbackTransport(2),
        client_interceptors=[rpc.RetryInterceptor(max_attempts=3)])
    fab.add_server(1).register("always", always)
    c = fab.channel(0, 1).call("always", [np.zeros(2, np.uint8)])
    fab.flush()
    assert c.done
    with pytest.raises(rpc.RpcError, match="still down"):
        c.reply_bufs()


def test_retry_respects_original_deadline_budget():
    """The fix: a retry never resets the per-call deadline. Backoffs
    are paid on the fabric clock against the ORIGINAL budget, and a
    retry whose backoff cannot fit in the remaining budget is not
    attempted at all — on the modeled clock this is exact: with a 1s
    budget and 0.4s doubling backoff, attempt 2 fits (0.4s) but
    attempt 3 would land at 1.2s > 1.0s and is abandoned."""
    def always(req):
        raise rpc.TransientError("still down")

    retry = rpc.RetryInterceptor(max_attempts=10, backoff_s=0.4)
    fab = rpc.RpcFabric(rpc.SimulatedTransport(2, NETWORKS["eth40g"]),
                        client_interceptors=[retry])
    fab.add_server(1).register("always", always)
    c = fab.channel(0, 1).call("always", [np.zeros(8, np.uint8)],
                               deadline_s=1.0)
    fab.flush()
    assert c.done
    with pytest.raises(rpc.RpcError, match="still down"):
        c.reply_bufs()
    assert retry.retries == 1            # only the 0.4s backoff fit
    assert retry.gave_up_budget == 1     # the 0.8s one was abandoned
    # the clock never ran past the original deadline chasing retries
    assert fab.transport.clock_s < 1.0


def test_retry_backoff_advances_modeled_clock():
    """Each retry pays its backoff on the fabric clock (deterministic
    on modeled transports), doubling per attempt."""
    seen = {"n": 0}

    def flaky(req):
        seen["n"] += 1
        if seen["n"] < 3:
            raise rpc.TransientError("warming up")
        return req

    retry = rpc.RetryInterceptor(max_attempts=5, backoff_s=0.1)
    fab = rpc.RpcFabric(rpc.SimulatedTransport(2, NETWORKS["eth40g"]),
                        client_interceptors=[retry])
    fab.add_server(1).register("flaky", flaky)
    c = fab.channel(0, 1).call("flaky", [np.zeros(8, np.uint8)])
    fab.flush()
    assert c.error is None and retry.retries == 2
    # 0.1 + 0.2 of backoff, plus the (tiny) flight costs
    assert fab.transport.clock_s >= 0.3


def test_method_spec_default_deadline_applied_by_stub():
    """MethodSpec.deadline_s is the per-method default: applied when an
    invocation passes none, overridden when one is passed, validated
    > 0 at declaration."""
    svc = rpc.ServiceDef("D", (
        rpc.MethodSpec("slow", rpc.UNARY, deadline_s=4.0),))
    fab = rpc.RpcFabric(rpc.SimulatedTransport(2, NETWORKS["eth40g"]))
    fab.add_server(1).add_service(svc, {"slow": lambda req: [(4,)]})
    stub = fab.stub(svc, 0, 1)
    c1 = stub.slow(None, sizes=[8])
    ctx1 = fab.context(c1.call_id)
    assert ctx1.deadline_s == pytest.approx(fab.now() + 4.0)
    c2 = stub.slow(None, sizes=[8], deadline_s=9.0)
    ctx2 = fab.context(c2.call_id)
    assert ctx2.deadline_s == pytest.approx(fab.now() + 9.0)
    fab.flush()
    assert c1.error is None and c2.error is None
    with pytest.raises(ValueError, match="deadline_s must be > 0"):
        rpc.MethodSpec("bad", rpc.UNARY, deadline_s=0.0)


def test_no_blanket_exception_handlers_inside_rpc():
    """The CI gate the deprecation step enforces, as a test: the
    fabric's failure semantics are the product, so a silent
    ``except Exception`` inside src/repro/rpc/ would swallow exactly
    the faults the fault tier exists to surface. Broad catches must go
    through the named HANDLER_FAULTS boundary in rpc/fabric.py."""
    root = pathlib.Path(__file__).resolve().parents[1] \
        / "src" / "repro" / "rpc"
    pat = re.compile(r"except +\(? *(Base)?Exception\b")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{p.name}:{i}: {line.strip()}")
    assert not offenders, offenders


def test_no_blanket_exception_handlers_inside_train():
    """The same gate extended to src/repro/train/: the trainer's retry
    loop used to catch blanket ``Exception`` and replay programming
    bugs as if they were node failures. Broad catches go through the
    named STEP_FAULTS boundary in train/trainer.py."""
    root = pathlib.Path(__file__).resolve().parents[1] \
        / "src" / "repro" / "train"
    pat = re.compile(r"except +\(? *(Base)?Exception\b")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{p.name}:{i}: {line.strip()}")
    assert not offenders, offenders
    from repro.train import trainer as trainer_mod
    assert trainer_mod.STEP_FAULTS == (RuntimeError, OSError)


def test_no_wall_clock_reads_inside_rpc():
    """The CI gate the wall-clock step enforces, as a test: the fabric
    runs on ``RpcFabric.now()`` (the modeled transport clock when there
    is one), so a stray ``time.time()``/``time.monotonic()`` inside
    src/repro/rpc/ would silently mix wall time into modeled spans and
    deadlines. Clock access is owned by fabric.py (``now()``) and the
    tracing/telemetry modules that consume it."""
    root = pathlib.Path(__file__).resolve().parents[1] \
        / "src" / "repro" / "rpc"
    pat = re.compile(r"time\.time\(|time\.monotonic\(")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        if p.name in ("tracing.py", "telemetry.py"):
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{p.name}:{i}: {line.strip()}")
    assert not offenders, offenders


def test_retry_not_triggered_by_permanent_errors():
    retry = rpc.RetryInterceptor(max_attempts=5)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        client_interceptors=[retry])

    def boom(req):
        raise ValueError("permanent")
    fab.add_server(1).register("boom", boom)
    c = fab.channel(0, 1).call("boom", [np.zeros(1, np.uint8)])
    fab.flush()
    assert retry.retries == 0
    with pytest.raises(rpc.RpcError, match="permanent"):
        c.reply_bufs()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_exceeded_on_stalled_stream():
    """A server stream stalled behind a zero-credit ChunkGate must fail
    with deadline-exceeded, not wait forever (or force uncredited
    admission past the stall)."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1024, window_msgs=4)
    fab.add_server(1).add_service(ECHO, dict(
        ECHO_HANDLERS,
        rng=lambda req: [[np.full(800, i, np.uint8)] for i in range(3)]))
    ch = fab.channel(0, 1)
    # drain the reverse window: the gate has zero credits, every chunk
    # stalls — the consumer never reads
    assert ch.rwindow.try_acquire(ch.rwindow.window_bytes)
    h = fab.stub(ECHO, 0, 1).rng.server_stream(
        [np.zeros(1, np.uint8)], deadline_s=0.05)
    fab.flush()                       # terminates via cancellation
    assert h.done
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        h.chunk_bufs()
    kinds = [e.kind for e in fab.cq.drain() if e.tag == h.call_id]
    assert kinds[-1] == "deadline_exceeded"
    assert len(ch.rx_gate) == 0       # gated chunks were dropped


def test_deadline_exceeded_is_deterministic_on_modeled_clock():
    """On the simulated transport the deadline wait advances the
    modeled clock instead of sleeping, so expiry is exact."""
    fab = rpc.RpcFabric(rpc.SimulatedTransport(2, NETWORKS["eth40g"]),
                        window_bytes=1024, window_msgs=4)
    fab.add_server(1).add_service(ECHO, dict(
        ECHO_HANDLERS, rng=lambda req: [(800,) for _ in range(3)]))
    ch = fab.channel(0, 1)
    assert ch.rwindow.try_acquire(ch.rwindow.window_bytes)
    h = fab.stub(ECHO, 0, 1).rng.server_stream(None, sizes=[1],
                                               deadline_s=5.0)
    fab.flush()
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        h.chunk_bufs()
    assert fab.transport.clock_s >= 5.0     # clock advanced to expiry


def test_deadline_interceptor_applies_default_and_counts():
    dl = rpc.DeadlineInterceptor(default_deadline_s=0.02)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1024, window_msgs=4,
                        client_interceptors=[dl])
    fab.add_server(1).add_service(ECHO, dict(
        ECHO_HANDLERS,
        rng=lambda req: [[np.full(900, i, np.uint8)] for i in range(2)]))
    ch = fab.channel(0, 1)
    assert ch.rwindow.try_acquire(ch.rwindow.window_bytes)
    h = fab.stub(ECHO, 0, 1).rng([np.zeros(1, np.uint8)])
    fab.flush()
    assert h.done and dl.exceeded == 1
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        h.chunk_bufs()


def test_deadline_does_not_fire_on_healthy_calls():
    fab = _echo_fabric()
    c = fab.stub(ECHO, 0, 1).inc([np.zeros(4, np.uint8)],
                                 deadline_s=30.0)
    fab.flush()
    assert np.array_equal(c.result()[0], np.ones(4, np.uint8))
    assert len(fab._ctx) == 0         # contexts do not accumulate


def test_stalled_deadline_unary_cancels_from_backlog():
    """A unary call stuck in the forward backlog behind a zero-credit
    window cancels at its deadline, and the backlog entry is purged."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1024, window_msgs=4)
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    ch = fab.channel(0, 1)
    assert ch.window.try_acquire(ch.window.window_bytes)
    c = fab.stub(ECHO, 0, 1).inc([np.zeros(100, np.uint8)],
                                 deadline_s=0.05)
    fab.flush()
    assert c.done
    with pytest.raises(rpc.RpcError, match="deadline exceeded"):
        c.result()
    assert not fab._backlog and ch.backlogged == 0


# ---------------------------------------------------------------------------
# incast fetch asymmetry
# ---------------------------------------------------------------------------

def test_scale_sizes():
    assert scale_sizes([1000, 4], 0.25) == [250, 1]
    assert scale_sizes([1000, 4], 1.0) == [1000, 4]
    assert scale_sizes([1000], 2.5) == [2500]
    with pytest.raises(AssertionError):
        scale_sizes([8], 0.0)


@pytest.mark.parametrize("ratio", [0.25, 1.0, 2.0])
def test_incast_exchange_fetch_ratio_matches_netmodel(ratio):
    spec = PayloadSpec(sizes=(65536,) * 4, scheme="t",
                       categories=("medium",) * 4)
    net = NETWORKS["eth10g"]
    fab = rpc.RpcFabric(rpc.SimulatedTransport(9, net))
    rep = rpc.incast_exchange(fab, list(spec.sizes), n_chunks=2,
                              fetch_ratio=ratio)
    assert rep.elapsed_s == pytest.approx(
        net.incast_round_time(spec, 8, n_chunks=2, fetch_ratio=ratio),
        rel=1e-9)


def test_incast_exchange_fetch_ratio_loopback_sizes():
    """Real-buffer path: the fetch chunks the workers receive are the
    scaled size (512, 128 pushed -> 128, 32 fetched at ratio 0.25)."""
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    bufs = _bufs([512, 128])
    rep = rpc.incast_exchange(fab, [512, 128], n_chunks=1, bufs=bufs,
                              fetch_ratio=0.25)
    assert rep.messages == 2 and fab.servers[0].calls_served == 1
    # a second stream against the handler the exchange registered
    # exposes the fetch payload directly
    h = fab.stub(rpc.INCAST_SERVICE, 1, 0).push_fetch([bufs])
    fab.flush()
    (chunk,) = h.chunk_bufs()
    assert [b.size for b in chunk] == [128, 32]


def test_bench_incast_fetch_ratio_end_to_end():
    """bench.run on the simulated transport: measured == projection
    with the asymmetric fetch, and asymmetry actually moves the
    number."""
    from repro.core import bench
    kw = dict(benchmark="incast", num_workers=8, transport="simulated",
              network="eth40g", stream_chunks=2)
    st = bench.run(BenchConfig(fetch_ratio=0.25, **kw))
    sym = bench.run(BenchConfig(fetch_ratio=1.0, **kw))
    assert st.model_projection["eth40g"] == pytest.approx(
        st.derived["rpcs_per_s"], rel=1e-6)
    assert st.derived["fetch_ratio"] == 0.25
    assert st.derived["rpcs_per_s"] > sym.derived["rpcs_per_s"]


def test_incast_exchange_rejects_changed_fetch_shape():
    """The fetch payload is baked into the server closure on first
    registration; silently serving the old shape for a new ratio would
    corrupt measurements — it must error instead."""
    fab = rpc.RpcFabric(rpc.SimulatedTransport(3, NETWORKS["eth40g"]))
    rpc.incast_exchange(fab, [1024], fetch_ratio=0.25)
    rpc.incast_exchange(fab, [1024], fetch_ratio=0.25)   # same: fine
    with pytest.raises(ValueError, match="already bound"):
        rpc.incast_exchange(fab, [1024], fetch_ratio=2.0)
    with pytest.raises(ValueError, match="already bound"):
        rpc.incast_exchange(fab, [2048], fetch_ratio=0.25)


def test_server_interceptors_reassignment_is_live():
    """Reassigning fabric.server_interceptors after add_server still
    reaches existing servers (the server holds a getter, not the
    list)."""
    log = []
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).add_service(ECHO, ECHO_HANDLERS)
    fab.server_interceptors = [_Rec("S", log)]
    fab.stub(ECHO, 0, 1).inc([np.zeros(4, np.uint8)])
    fab.flush()
    assert log == ["S.recv", "S.done"]


def test_bench_comm_rejects_scaling_axes_on_fixed_benchmarks(capsys):
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--sweep", "workers",
                         "--benchmark", "p2p_latency"])
    assert "scales with workers" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        bench_comm.main(["--sweep", "stream_chunks",
                         "--benchmark", "fully_connected",
                         "--transport", "simulated"])
    assert "streaming benchmark" in capsys.readouterr().err


def test_bench_comm_rejects_bad_fetch_ratio():
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--benchmark", "incast", "--fetch-ratio", "0"])


# ---------------------------------------------------------------------------
# sweep scaling axes
# ---------------------------------------------------------------------------

def test_bench_comm_sweep_scaling_axes(tmp_path):
    from repro.launch import bench_comm
    out = tmp_path / "rows.json"
    bench_comm.main(["--sweep", "workers,stream_chunks",
                     "--benchmark", "ring", "--transport", "simulated",
                     "--network", "eth40g", "--json", str(out)])
    rows = json.loads(out.read_text())["rows"]
    assert len(rows) == 4 * 4
    combos = {(r["workers"], r["stream_chunks"]) for r in rows}
    assert combos == {(w, c) for w in (2, 4, 8, 16)
                      for c in (1, 2, 4, 8)}
    assert all(r["value"] > 0 for r in rows)
    # scaling curve: ring round time grows with chunk count, so the
    # per-chunk throughput at fixed workers is not constant in chunks
    by_w4 = {r["stream_chunks"]: r["value"] for r in rows
             if r["workers"] == 4}
    assert len(set(round(v, 3) for v in by_w4.values())) > 1


def test_bench_comm_rejects_duplicate_sweep_axes(capsys):
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--sweep", "workers,workers",
                         "--benchmark", "ring",
                         "--transport", "simulated"])
    assert "duplicate" in capsys.readouterr().err


def test_bench_comm_json_carries_rpc_metrics(tmp_path):
    from repro.launch import bench_comm
    out = tmp_path / "row.json"
    bench_comm.main(["--benchmark", "incast", "--transport", "simulated",
                     "--network", "eth40g", "--num-workers", "4",
                     "--fetch-ratio", "0.25", "--json", str(out)])
    (row,) = json.loads(out.read_text())["rows"]
    m = row["rpc_metrics"]["Incast/push_fetch"]
    assert m["calls"] > 0 and m["ok"] == m["calls"]
    assert m["latency_us"]["p50"] > 0
    assert m["latency_us"]["p95"] >= m["latency_us"]["p50"]


# ---------------------------------------------------------------------------
# migration: deprecated shims delegate to stubs; no direct
# registration remains outside repro.rpc
# ---------------------------------------------------------------------------

def test_rpc_generate_shims_delegate_to_stub(monkeypatch):
    from repro.serve import engine as E
    tokens = np.arange(6, dtype=np.int32).reshape(2, 3)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).add_service(E.SERVE_SERVICE, {
        "generate": lambda bufs: E.encode_generate_reply(tokens),
        "generate_stream": lambda bufs: [
            [E._i32_buf(tokens[:, i])] for i in range(3)],
    })
    ch = fab.channel(0, 1)
    used = []
    real = E.serve_stub
    monkeypatch.setattr(
        E, "serve_stub", lambda c: (used.append(c), real(c))[1])
    with pytest.warns(DeprecationWarning, match="rpc_generate"):
        out = E.rpc_generate(ch, np.zeros((2, 4), np.int32))
    assert used == [ch], "rpc_generate must delegate through the stub"
    assert np.array_equal(out, tokens)
    out2 = E.rpc_generate_stream(ch, np.zeros((2, 4), np.int32))
    assert used == [ch, ch]
    assert np.array_equal(out2, tokens)


def test_no_direct_registration_outside_rpc():
    """The deprecation gate the CI step enforces, as a test: every
    module outside src/repro/rpc/ goes through ServiceDef + Stub, and
    transports are built through ``rpc.make_transport`` — never by
    constructing a Transport class directly."""
    root = pathlib.Path(__file__).resolve().parents[1] / "src"
    pat = re.compile(
        r"register_unary|register_server_stream|register_bidi"
        r"|call_unary|\.register\("
        r"|(?:Loopback|Simulated|Cluster|Collective)Transport\s*\(")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root)
        if rel.parts[:2] == ("repro", "rpc"):
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, offenders


def test_no_rpc_generate_callers_outside_shim():
    """The rpc_generate deprecation gate the CI step enforces, as a
    test: the one-release shim has no internal callers — everything
    dispatches through ``serve_stub`` (the generated Stub surface)."""
    root = pathlib.Path(__file__).resolve().parents[1] / "src"
    pat = re.compile(r"\brpc_generate\s*\(")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root)
        if rel.as_posix() == "repro/serve/engine.py":
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, offenders


def test_no_direct_bufpool_construction_outside_rpc():
    """The zero-copy pool gate the CI step enforces, as a test: the
    shared BufferPool is registry-owned — every consumer outside
    src/repro/rpc/ goes through ``rpc.get_pool`` so pool ids stay
    process-unique and pre-registered regions are actually shared
    (a privately constructed pool would silently break the zero-copy
    descriptor contract: senders and receivers must resolve the same
    pool id to the same memory)."""
    root = pathlib.Path(__file__).resolve().parents[1] / "src"
    pat = re.compile(r"\bBufferPool\s*\(")
    offenders = []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root)
        if rel.parts[:2] == ("repro", "rpc"):
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, offenders
