"""End-to-end behaviour tests for the system: serve engine generation,
bench-suite wiring, sharding rule coherence, config registry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (SHAPES, cells, get_config, get_reduced_config,
                           get_shape, list_archs)
from repro.models import forward, init_params
from repro.models.model import param_logical_axes, state_logical_axes
from repro.parallel import NO_MESH
from repro.serve.engine import ServeConfig, ServeEngine


def test_registry_covers_assignment():
    assert len(list_archs()) == 10
    assert len(SHAPES) == 4
    runnable = cells()
    allc = cells(include_skipped=True)
    assert len(allc) == 40            # the assigned 10x4 grid
    assert len(runnable) == 33        # documented skips (DESIGN.md §4)
    skips = [(a, s, r) for a, s, r in allc if r is not None]
    assert all(r for _, _, r in skips)
    # encoder-only: both decode shapes skipped
    hub = {s for a, s, r in skips if a == "hubert-xlarge"}
    assert hub == {"decode_32k", "long_500k"}


def test_param_counts_match_published_sizes():
    expected = {  # billions, +-12%
        "hubert-xlarge": 0.96, "mixtral-8x7b": 46.7,
        "kimi-k2-1t-a32b": 1041.0, "qwen1.5-4b": 4.0,
        "nemotron-4-15b": 15.0, "qwen3-8b": 8.2, "gemma2-9b": 9.2,
        "internvl2-76b": 70.0, "rwkv6-1.6b": 1.6,
        "jamba-1.5-large-398b": 398.0,
    }
    for arch, want in expected.items():
        got = get_config(arch).model.num_params() / 1e9
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_moe_active_params():
    k2 = get_config("kimi-k2-1t-a32b").model
    assert 25 < k2.num_active_params() / 1e9 < 40  # ~32B active


def test_logical_axes_match_param_tree():
    for arch in list_archs():
        cfg = get_reduced_config(arch)
        params = init_params(jax.random.PRNGKey(0), cfg)
        axes = param_logical_axes(cfg)
        ps, pdef = jax.tree.flatten(params)
        axs, adef = jax.tree.flatten(axes)
        assert pdef == adef, arch
        for p, a in zip(ps, axs):
            assert p.ndim == len(a), (arch, p.shape, a)


def test_state_axes_match_state_tree():
    for arch in list_archs():
        cfg = get_reduced_config(arch)
        if cfg.model.is_encoder:
            continue
        from repro.models import init_states
        st = init_states(NO_MESH, cfg, batch=2, max_seq=32)
        axes = state_logical_axes(cfg, batch=2)
        sdef = jax.tree.structure(st)
        adef = jax.tree.structure(axes)
        assert sdef == adef, arch


@pytest.mark.parametrize("arch", ["qwen3-8b", "rwkv6-1.6b",
                                  "mixtral-8x7b"])
def test_serve_engine_generates(arch):
    cfg = get_reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=4))
    prompts = np.random.default_rng(0).integers(
        0, cfg.model.vocab_size, (2, 8), dtype=np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.model.vocab_size).all()


def test_serve_greedy_matches_forward():
    cfg = get_reduced_config("qwen3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(NO_MESH, cfg, params,
                      ServeConfig(max_seq=64, max_new_tokens=1))
    prompts = np.random.default_rng(1).integers(
        0, cfg.model.vocab_size, (2, 8), dtype=np.int32)
    out = eng.generate(prompts)
    from repro.models import logits_fn
    h, _, _ = forward(NO_MESH, cfg, params, tokens=jnp.asarray(prompts),
                      mode="train")
    ref = np.asarray(jnp.argmax(
        logits_fn(NO_MESH, cfg, params, h)[:, -1], axis=-1))
    assert (out[:, 0] == ref).all()


def test_serve_rejects_encoder():
    cfg = get_reduced_config("hubert-xlarge")
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(AssertionError):
        ServeEngine(NO_MESH, cfg, params)


def test_encoder_is_bidirectional():
    """hubert must see future frames (encoder), causal LMs must not."""
    cfg = get_reduced_config("hubert-xlarge")
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(2)
    e1 = jax.random.normal(key, (1, 16, cfg.model.d_model))
    e2 = e1.at[:, -1].set(-e1[:, -1])  # change only the LAST frame
    h1, _, _ = forward(NO_MESH, cfg, params, embeds=e1, mode="train")
    h2, _, _ = forward(NO_MESH, cfg, params, embeds=e2, mode="train")
    # position 0 output must change for an encoder
    assert float(jnp.max(jnp.abs(h1[:, 0] - h2[:, 0]))) > 1e-6

    cfgc = get_reduced_config("qwen3-8b")
    pc = init_params(jax.random.PRNGKey(0), cfgc)
    t1 = jax.random.randint(key, (1, 16), 0, cfgc.model.vocab_size)
    t2 = t1.at[:, -1].set((t1[:, -1] + 1) % cfgc.model.vocab_size)
    c1, _, _ = forward(NO_MESH, cfgc, pc, tokens=t1, mode="train")
    c2, _, _ = forward(NO_MESH, cfgc, pc, tokens=t2, mode="train")
    np.testing.assert_allclose(np.asarray(c1[:, :-1]),
                               np.asarray(c2[:, :-1]), atol=1e-6)


def test_sliding_window_actually_limits_context():
    import repro.configs.base as base
    cfg = get_reduced_config("mixtral-8x7b")
    att = dataclasses.replace(cfg.model.attention, sliding_window=4)
    m = dataclasses.replace(cfg.model, moe=None, attention=att,
                            family="dense")
    cfg = cfg.replace(model=m)
    params = init_params(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                            cfg.model.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.model.vocab_size)
    h1, _, _ = forward(NO_MESH, cfg, params, tokens=t1, mode="train")
    h2, _, _ = forward(NO_MESH, cfg, params, tokens=t2, mode="train")
    # with window 4 and 2 layers, position 15 cannot see position 0
    np.testing.assert_allclose(np.asarray(h1[:, -1]),
                               np.asarray(h2[:, -1]), atol=1e-6)
    del base
