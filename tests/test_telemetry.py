"""Bounded-histogram telemetry: the exact regime is byte-identical to
np.percentile over the raw samples (the behavior the MetricsInterceptor
tests pin), the bucketed regime is bounded-memory with monotone,
conservatively-rounded percentiles, and the HistogramRegistry is the
shared sink interceptors record into."""
import numpy as np
import pytest

from repro import rpc
from repro.rpc.telemetry import (EXACT_CAP, BoundedHistogram,
                                 HistogramRegistry)


# ---------------------------------------------------------------------------
# exact regime
# ---------------------------------------------------------------------------

def test_exact_regime_matches_numpy():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(-9, 1.5, 1000)
    h = BoundedHistogram()
    h.extend(samples)
    assert not h.bucketed
    for q in (0, 10, 50, 95, 99, 99.9, 100):
        assert h.percentile(q) == float(np.percentile(samples, q))
    assert h.mean == pytest.approx(samples.mean())
    assert h.min == samples.min() and h.max == samples.max()
    assert h.count == 1000 and h.total == pytest.approx(samples.sum())


def test_empty_histogram():
    h = BoundedHistogram()
    assert h.percentile(50) == 0.0 and h.mean == 0.0
    assert h.snapshot() == {"count": 0}


# ---------------------------------------------------------------------------
# bucketed regime
# ---------------------------------------------------------------------------

def test_fold_preserves_exact_aggregates_and_bounds_memory():
    h = BoundedHistogram(exact_cap=100)
    rng = np.random.default_rng(1)
    samples = rng.lognormal(-8, 1.0, 5000)
    h.extend(samples)
    assert h.bucketed
    assert h.count == 5000
    assert h.total == pytest.approx(samples.sum())
    assert h.min == samples.min() and h.max == samples.max()
    # memory is the fixed bucket array, not the sample list
    assert h._exact is None
    assert len(h._counts) == h._n_buckets
    assert int(h._counts.sum()) == 5000


def test_bucketed_percentiles_monotone_and_close():
    h = BoundedHistogram(exact_cap=10)
    rng = np.random.default_rng(2)
    samples = rng.lognormal(-9, 2.0, 20000)
    h.extend(samples)
    assert h.bucketed
    qs = [0, 1, 10, 25, 50, 75, 90, 99, 99.9, 100]
    vals = h.percentiles(qs)
    assert vals == sorted(vals)                      # monotone in q
    assert vals[0] == h.min and vals[-1] == h.max    # extremes exact
    # bucket upper edges: never under-report, and within one bucket's
    # relative resolution (10^(1/16) ~ 15.5%) of the true percentile
    for q, v in zip(qs[1:-1], vals[1:-1]):
        true = float(np.percentile(samples, q))
        assert v >= true * 0.999
        assert v <= true * 10 ** (1 / 16) * 1.001


def test_bucketed_handles_out_of_range_values():
    h = BoundedHistogram(exact_cap=2, lo=1e-6, hi=1.0)
    h.extend([1e-9, 5e-9, 2.0, 3.0, 0.5])     # under + over + in range
    assert h.bucketed
    assert h.percentile(100) == 3.0
    assert h.percentile(0) == 1e-9
    # the overflow bucket reports the exact max, not hi
    assert h.percentile(99) <= 3.0


def test_snapshot_keys_and_default_cap():
    h = BoundedHistogram()
    h.extend(float(i) / 1000 for i in range(10))
    snap = h.snapshot()
    assert set(snap) == {"count", "mean", "min", "max",
                         "p50", "p95", "p99", "p999"}
    assert EXACT_CAP == 4096 and h.exact_cap == EXACT_CAP


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_shared_sink():
    reg = HistogramRegistry(exact_cap=8)
    a = reg.hist("latency:m")
    assert reg.hist("latency:m") is a          # one histogram per name
    assert a.exact_cap == 8                     # registry params apply
    a.record(0.5)
    assert reg.get("latency:m").count == 1
    assert reg.get("nope") is None
    assert reg.names() == ["latency:m"]
    assert reg.snapshot()["latency:m"]["count"] == 1
    reg.remove("latency:m")
    assert reg.names() == []
    reg.hist("x").record(1.0)
    reg.clear()
    assert reg.names() == []


# ---------------------------------------------------------------------------
# MetricsInterceptor integration (the refactor the registry exists for)
# ---------------------------------------------------------------------------

def _echo_fabric(metrics):
    fab = rpc.RpcFabric(rpc.make_transport("simulated", 2,
                                           network="eth40g"),
                        client_interceptors=[metrics])
    fab.add_server(1).register("echo", lambda bufs: bufs)
    return fab


def test_metrics_interceptor_records_into_registry():
    metrics = rpc.MetricsInterceptor()
    fab = _echo_fabric(metrics)
    ch = fab.channel(0, 1)
    for _ in range(4):
        ch.call("echo", [np.zeros(64, np.uint8)])
    fab.flush()
    h = metrics.histogram("echo")
    assert isinstance(h, BoundedHistogram) and h.count == 4
    assert "latency:echo" in metrics.registry.names()
    snap = metrics.snapshot()["echo"]
    assert set(snap["latency_us"]) == {"mean", "p50", "p95", "p99"}
    assert snap["latency_us"]["p50"] == pytest.approx(
        h.percentile(50) * 1e6)


def test_metrics_interceptors_can_share_one_registry():
    reg = HistogramRegistry()
    m1 = rpc.MetricsInterceptor(registry=reg)
    m2 = rpc.MetricsInterceptor(registry=reg)
    fab = _echo_fabric(m1)
    ch = fab.channel(0, 1)
    ch.call("echo", [np.zeros(8, np.uint8)])
    fab.flush()
    # the second interceptor sees the first one's distribution: one
    # bounded copy per process, not one list per interceptor
    assert m2.registry.get("latency:echo").count == 1
    m1.reset()
    assert reg.get("latency:echo") is None     # reset removes its keys


# ---------------------------------------------------------------------------
# extreme tails (p999 / p9999): the percentiles SLO reports lean on
# ---------------------------------------------------------------------------

def test_extreme_tails_exact_regime_match_numpy():
    # 4096 samples fit the exact cap, so p999/p9999 interpolate over
    # the raw data exactly like np.percentile — including the far
    # tail, where a single sample dominates
    rng = np.random.default_rng(7)
    samples = rng.lognormal(-9, 2.5, EXACT_CAP)
    h = BoundedHistogram()
    h.extend(samples)
    assert not h.bucketed
    for q in (99.9, 99.99):
        assert h.percentile(q) == float(np.percentile(samples, q))
    assert h.percentile(99.9) <= h.percentile(99.99) <= h.max


def test_extreme_tails_folded_regime_conservative_and_monotone():
    rng = np.random.default_rng(8)
    samples = rng.lognormal(-9, 2.0, 50000)
    h = BoundedHistogram(exact_cap=50)
    h.extend(samples)
    assert h.bucketed
    p999 = h.percentile(99.9)
    p9999 = h.percentile(99.99)
    # conservative: bucket-upper-edge rounding can only over-report a
    # tail latency, never hide it
    assert p999 >= float(np.percentile(samples, 99.9))
    assert p9999 >= float(np.percentile(samples, 99.99))
    # monotone in q, clamped to the exact max
    assert h.percentile(99) <= p999 <= p9999 <= h.max
    assert h.max == samples.max()


def test_extreme_tails_single_outlier_survives_fold():
    # one 10x outlier among 5k fast samples: p9999 must report it
    # (rank 99.99% of 5001 = 5000.5 > 5000 lands on the outlier) even
    # after folding
    h = BoundedHistogram(exact_cap=10)
    h.extend(np.full(5000, 1e-6))
    h.record(1e-5)
    assert h.bucketed
    assert h.percentile(99.99) >= 1e-5 * 0.999  # the outlier's bucket
    assert h.percentile(50) < 2e-6
    assert h.percentile(99.99) <= h.max == 1e-5


def test_extreme_tails_quantization_error_bounded_by_resolution():
    # the folded p999 overshoot is bounded by one bucket's width:
    # ratio upper/lower edge = 10**(1/buckets_per_decade)
    rng = np.random.default_rng(9)
    samples = rng.lognormal(-9, 1.0, 30000)
    h = BoundedHistogram(exact_cap=10, buckets_per_decade=32)
    h.extend(samples)
    step = 10.0 ** (1.0 / 32)
    for q in (99.0, 99.9, 99.99):
        exact = float(np.percentile(samples, q))
        assert exact <= h.percentile(q) <= exact * step * 1.01
