"""Distributed tracing over the fabric: span trees on the modeled
clock, the phase-partition invariant, trace-id propagation in the frame
header, Chrome trace-event export, the bench_comm phase breakdown /
--trace / schema-3 JSON surface, and the perf-baseline telemetry
round trip. Ends with the acceptance scenario: a cluster-transport
serve run under faults whose retried, failed-over server-stream call
shows stall -> fault -> backoff -> re-route -> delivery as nested
spans in the exported Chrome JSON."""
import io
import json

import numpy as np
import pytest

from repro import rpc
from repro.rpc.framing import decode, encode
from repro.rpc.tracing import PHASES

SIZES = [2048, 256]


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


def _sim_fabric(tracer, n=2, **kw):
    fab = rpc.RpcFabric(rpc.make_transport("simulated", n,
                                           network="eth40g"),
                        tracer=tracer, **kw)
    fab.add_server(1).register("echo", lambda bufs: bufs)
    return fab


def _assert_partition(root, rel_tol=1e-9):
    """The tracing invariant: a closed call's phases are a contiguous
    non-overlapping partition of [start, end] summing to the
    end-to-end latency."""
    phases = sorted((s for s in root.phase_spans() if s.closed),
                    key=lambda s: (s.start_s, s.span_id))
    assert phases, "closed call must have phase spans"
    assert phases[0].start_s == root.start_s
    assert phases[-1].end_s == root.end_s
    for a, b in zip(phases, phases[1:]):
        assert a.end_s == b.start_s        # contiguous, no overlap
    total = sum(s.duration_s for s in phases)
    assert total == pytest.approx(root.duration_s, rel=rel_tol, abs=0.0)


# ---------------------------------------------------------------------------
# span tree + phases, unary
# ---------------------------------------------------------------------------

def test_unary_span_tree_and_exact_phase_partition():
    tracer = rpc.Tracer()
    fab = _sim_fabric(tracer)
    c = fab.channel(0, 1).call("echo", _bufs(SIZES))
    fab.flush()
    assert c.error is None
    (root,) = tracer.calls()
    assert root.closed and root.name == "echo"
    assert root.attrs["outcome"] == "replied"
    assert root.attrs["attempts"] == 1
    assert len(root.attempt_spans()) == 1
    _assert_partition(root)
    by_phase = {s.name for s in root.phase_spans()}
    # simulated unary: queued, on the wire, served, reply in flight
    assert {"queue", "wire", "server", "reply"} <= by_phase
    # wire record spans (request + reply) on the sender tracks
    wires = [s for s in root.walk() if s.category == "wire"]
    assert {w.attrs["reply"] for w in wires} == {False, True}
    # the handler span landed on the SERVER endpoint's track
    handlers = [s for s in root.walk() if s.category == "server"
                and s.name.startswith("handler")]
    assert handlers and all(h.endpoint == 1 for h in handlers)
    # one trace id spans all of it, and live state was reclaimed
    assert {s.trace_id for s in root.walk()} == {root.trace_id}
    assert not tracer._by_call and not tracer._by_trace


def test_trace_id_rides_the_frame_header():
    tracer = rpc.Tracer()
    fab = _sim_fabric(tracer)
    ch = fab.channel(0, 1)
    c = ch.call("echo", _bufs(SIZES))
    ctx = fab.context(c.call_id)
    assert ctx.trace_id == tracer.calls()[0].trace_id > 0
    fab.flush()
    # the header word round-trips the id through encode/decode, and
    # replies inherit it (how the reply wire span found its call)
    f = rpc.make_frame(7, "echo", _bufs(SIZES))
    f = f.__class__(**{**f.__dict__, "trace_id": 41})
    assert decode(encode(f)).trace_id == 41
    assert f.reply([np.zeros(1, np.uint8)]).trace_id == 41


def test_credit_stall_phase_recorded():
    """With a one-message window the second call queues behind the
    first's credit — the stall is its own phase, and the partition
    still holds."""
    tracer = rpc.Tracer()
    fab = _sim_fabric(tracer, window_msgs=1)
    ch = fab.channel(0, 1)
    c1 = ch.call("echo", _bufs(SIZES))
    c2 = ch.call("echo", _bufs(SIZES, seed=1))
    fab.flush()
    assert c1.error is None and c2.error is None
    roots = tracer.calls()
    assert len(roots) == 2
    stalled = [r for r in roots
               if any(s.name == "credit_stall" for s in r.phase_spans())]
    assert stalled, "window_msgs=1 must stall the second call"
    for r in roots:
        _assert_partition(r)


def test_phase_breakdown_sums_to_end_to_end():
    tracer = rpc.Tracer()
    fab = _sim_fabric(tracer)
    ch = fab.channel(0, 1)
    for i in range(5):
        ch.call("echo", _bufs(SIZES, seed=i))
    fab.flush()
    bd = tracer.phase_breakdown()
    assert set(bd) == {"echo"}
    row = bd["echo"]
    assert row["calls"] == 5
    assert set(row["phases"]) == set(PHASES)
    total = sum(row["phases"].values())
    assert abs(total - row["end_to_end_s"]) \
        <= 0.01 * row["end_to_end_s"]       # the 1% acceptance bound
    assert row["end_to_end_s"] > 0


def test_tracer_span_cap_stops_tracking():
    """At the cap, NEW calls stop being tracked (dropped counts);
    already-tracked calls still close their trees."""
    tracer = rpc.Tracer(max_spans=4)
    fab = _sim_fabric(tracer)
    ch = fab.channel(0, 1)
    for i in range(6):
        ch.call("echo", _bufs([64]))
    fab.flush()
    assert len(tracer.calls()) == 2         # cap hit after two starts
    assert tracer.dropped == 4
    for root in tracer.calls():
        assert root.closed
        _assert_partition(root)
    tracer.clear()
    assert tracer.spans() == [] and tracer.dropped == 0


# ---------------------------------------------------------------------------
# Chrome export
# ---------------------------------------------------------------------------

def test_chrome_export_shape(tmp_path):
    tracer = rpc.Tracer()
    fab = _sim_fabric(tracer)
    fab.channel(0, 1).call("echo", _bufs(SIZES))
    fab.flush()
    out = tmp_path / "trace.json"
    tracer.export_chrome(str(out))
    doc = json.loads(out.read_text())
    ev = doc["traceEvents"]
    meta = [e for e in ev if e["ph"] == "M"]
    # process name + one named track per endpoint that recorded spans
    assert {m["name"] for m in meta} == {"process_name", "thread_name"}
    assert {m["tid"] for m in meta if m["name"] == "thread_name"} \
        == {0, 1}
    xs = [e for e in ev if e["ph"] == "X"]
    assert xs
    for e in xs:
        assert e["dur"] >= 0 and e["pid"] == 0
        assert e["args"]["trace_id"] >= 1
    assert {e["cat"] for e in xs} >= {"call", "attempt", "phase",
                                      "wire", "server"}
    # file-like export produces the same document
    buf = io.StringIO()
    tracer.export_chrome(buf)
    assert json.loads(buf.getvalue()) == doc


# ---------------------------------------------------------------------------
# bench_comm surface: phases in --json, --trace, schema, baseline
# ---------------------------------------------------------------------------

def _bench_json(tmp_path, *extra):
    from repro.launch import bench_comm
    out = tmp_path / "rows.json"
    bench_comm.main(["--benchmark", "incast", "--transport", "simulated",
                     "--network", "eth40g", "--num-workers", "3",
                     "--json", str(out), *extra])
    return json.loads(out.read_text())


def test_bench_comm_json_schema_and_phase_breakdown(tmp_path, capsys):
    doc = _bench_json(tmp_path)
    assert set(doc) == {"schema", "rows"}      # versioned envelope
    assert doc["schema"] == 3
    (row,) = doc["rows"]
    phases = row["rpc_phases"]["Incast/push_fetch"]
    assert phases["calls"] > 0
    total = sum(phases["phases"].values())
    assert abs(total - phases["end_to_end_s"]) \
        <= 0.01 * phases["end_to_end_s"]
    assert "phase breakdown" in capsys.readouterr().out


def test_bench_comm_trace_flag_writes_chrome_json(tmp_path):
    trace = tmp_path / "out.json"
    _bench_json(tmp_path, "--trace", str(trace))
    doc = json.loads(trace.read_text())
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert any(e["cat"] == "call" for e in xs)


def test_bench_comm_trace_flag_validation(capsys):
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--benchmark", "p2p_latency",
                         "--trace", "x.json"])
    assert "fabric benchmark" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        bench_comm.main(["--sweep", "scheme", "--benchmark", "incast",
                         "--transport", "simulated",
                         "--trace", "x.json"])
    assert "single run" in capsys.readouterr().err
    # --baseline/--check-baseline run no benchmark, so combining them
    # with --trace used to silently write no trace file; now rejected
    for flag in ("--baseline", "--check-baseline"):
        with pytest.raises(SystemExit):
            bench_comm.main(["--benchmark", "incast", "--transport",
                             "simulated", "--trace", "x.json",
                             flag, "b.json"])
        assert "without running a benchmark" in capsys.readouterr().err


def test_baseline_collect_check_and_drift(tmp_path, capsys):
    from repro.core import bench
    from repro.launch import bench_comm
    base = tmp_path / "base.json"
    bench_comm.main(["--baseline", str(base)])
    doc = json.loads(base.read_text())
    assert doc["schema"] == bench.BASELINE_SCHEMA
    assert set(doc["families"]) == {
        "p2p_latency", "p2p_bandwidth", "ps_throughput",
        "fully_connected", "ring", "incast",
        "allreduce_ring", "allreduce_tree", "allreduce_rsag",
        "train_step_ps", "train_step_allreduce"}
    for fam in doc["families"].values():
        assert fam["round_time_s"] > 0 and fam["throughput"] > 0
    assert doc["train_crossover"]["allreduce_wins_from"] is not None
    # clean check: the numbers are deterministic, zero drift
    bench_comm.main(["--check-baseline", str(base)])
    assert "baseline OK" in capsys.readouterr().out
    # a tampered family trips the gate with exit code 1
    doc["families"]["ring"]["throughput"] *= 1.05
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    with pytest.raises(SystemExit) as ei:
        bench_comm.main(["--check-baseline", str(bad)])
    assert ei.value.code == 1
    assert "BASELINE DRIFT: ring.throughput" in capsys.readouterr().out
    # a tightened tolerance is honored end to end
    problems = bench.check_baseline(doc, rel_tol=0.10)
    assert problems == []


def test_committed_baseline_matches_fresh_run():
    """The checked-in benchmarks/BENCH_fabric.json must diff clean —
    the same gate CI runs."""
    import pathlib

    from repro.core import bench
    path = pathlib.Path(__file__).resolve().parents[1] \
        / "benchmarks" / "BENCH_fabric.json"
    doc = json.loads(path.read_text())
    assert bench.check_baseline(doc, rel_tol=0.01) == []


# ---------------------------------------------------------------------------
# acceptance: cluster serve under faults — one server-stream call whose
# trace shows stall -> fault -> backoff -> re-route -> delivery
# ---------------------------------------------------------------------------

def _stream_handlers(name, exhaust_once):
    from repro.serve.engine import _i32_buf, decode_generate_request

    def generate_stream(bufs):
        if exhaust_once.pop(name, None):
            raise rpc.ResourceExhausted(f"{name} overloaded")
        prompts, mnt = decode_generate_request(bufs)
        return [[_i32_buf(np.full(prompts.shape[0], int(name[-1]),
                                  np.int32))]
                for _ in range(max(mnt, 1))]

    return {"generate_stream": generate_stream,
            "generate": lambda bufs: bufs}


def test_acceptance_failed_over_stream_trace(tmp_path):
    from repro.serve.engine import SERVE_SERVICE, ShardedServeStub
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps"),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0")))
    tracer = rpc.Tracer()
    retry = rpc.RetryInterceptor(max_attempts=4, backoff_s=1e-3)
    transport = rpc.make_transport("cluster", cluster=cluster)
    # the call's FIRST frame on worker0 -> ps0 is lost to a link fault
    transport = rpc.make_transport("fault", inner=transport, seed=7,
                                   fault_rate=1.0, max_faults=1,
                                   links=[(2, 0)])
    fab = rpc.RpcFabric(transport, client_interceptors=[retry],
                        window_msgs=1, tracer=tracer)
    exhaust_once = {"ps0": True}   # ps0 sheds the retried attempt once
    for name in ("ps0", "ps1"):
        fab.add_server(name).add_service(
            SERVE_SERVICE, _stream_handlers(name, exhaust_once))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    prompts = np.zeros((2, 4), np.int32)
    call = stub.generate_stream(prompts, 3)    # round robin -> ps0
    fab.flush()
    assert call.done and call.error is None, call.error

    (root,) = tracer.calls()
    assert root.closed and root.attrs["outcome"] == "stream_end"
    # attempt 1 -> ps0 (lost to the link fault), attempt 2 -> ps0
    # (shed: resource exhausted), attempt 3 re-routed -> ps1
    attempts = root.attempt_spans()
    assert [a.attrs["dst"] for a in attempts] == ["ps0", "ps0", "ps1"]
    assert root.attrs["attempts"] == 3
    # the fault is on attempt 1's subtree, as an instant span
    (fault,) = [s for s in root.walk() if s.category == "fault"]
    assert fault.parent_id == attempts[0].span_id
    assert fault.name == "link_fault worker0->ps0"
    # backoff was paid on the fabric clock between attempts
    backoffs = [s for s in root.phase_spans() if s.name == "backoff"]
    assert backoffs and all(s.duration_s > 0 for s in backoffs)
    # the one-message window stalled the multi-chunk stream somewhere
    assert any(s.name == "credit_stall" for s in root.phase_spans())
    # delivery: reply-direction wire spans from the failover target
    reply_wires = [s for s in root.walk() if s.category == "wire"
                   and s.attrs["reply"] and s.endpoint == 1]
    assert reply_wires, "delivered chunks must trace from ps1"
    # the handler ran on ps1's track, attributed cross-endpoint via
    # the propagated trace id
    handlers = [s for s in root.walk() if s.category == "server"
                and s.name.startswith("handler")]
    assert any(h.endpoint == 1 for h in handlers)
    assert {s.trace_id for s in root.walk()} == {root.trace_id}
    _assert_partition(root)

    # ... and the whole causal chain survives Chrome export
    out = tmp_path / "acceptance.json"
    tracer.export_chrome(str(out))
    ev = json.loads(out.read_text())["traceEvents"]
    names = [e["name"] for e in ev if e["ph"] == "X"]
    for needed in ("attempt 1", "attempt 2", "attempt 3", "backoff",
                   "credit_stall", "link_fault worker0->ps0"):
        assert needed in names, needed
    tracks = {e["args"]["name"] for e in ev
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"endpoint ps1", "endpoint worker0"} <= tracks
