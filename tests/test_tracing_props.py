"""Property tests for distributed tracing: under random payload mixes,
window pressure, fault schedules, and shard failover, every closed
call's span tree stays well-nested, its phases form a contiguous
non-overlapping partition summing to the end-to-end latency, and one
trace id survives header round-trips, retries, and re-routes. Skips
cleanly when hypothesis is absent; runs with --hypothesis-profile=ci
in CI."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro import rpc
from repro.rpc import framing


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


def _check_tree(root, rel_tol=1e-9):
    """The invariants every closed call must satisfy."""
    assert root.closed
    # one trace id across the whole tree
    assert {s.trace_id for s in root.walk()} == {root.trace_id}
    # well-nested: every closed child lies within its parent's window
    # (phases/wire/server nest in attempts; attempts + backoff in the
    # root)
    by_id = {s.span_id: s for s in root.walk()}
    for s in root.walk():
        if s.parent_id is None or not s.closed:
            continue
        parent = by_id[s.parent_id]
        assert parent.closed
        assert s.start_s >= parent.start_s - 1e-12
        assert s.end_s <= parent.end_s + 1e-12
    # phase partition: contiguous, non-overlapping, sums to e2e
    phases = sorted((s for s in root.phase_spans() if s.closed),
                    key=lambda s: (s.start_s, s.span_id))
    assert phases
    assert phases[0].start_s == root.start_s
    assert phases[-1].end_s == root.end_s
    for a, b in zip(phases, phases[1:]):
        assert a.end_s == b.start_s
    total = sum(p.duration_s for p in phases)
    assert total == pytest.approx(root.duration_s, rel=rel_tol, abs=0.0)


# ---------------------------------------------------------------------------
# trace-id header word round-trip
# ---------------------------------------------------------------------------

@given(trace_id=st.integers(0, framing.MAX_TRACE_ID),
       sizes=st.lists(st.integers(0, 1024), min_size=0, max_size=6),
       serialized=st.booleans())
@settings(max_examples=60, deadline=None)
def test_trace_id_header_roundtrip(trace_id, sizes, serialized):
    """trace_id survives header encode/parse, the full wire round trip,
    and is inherited by replies and stream chunks."""
    f = framing.make_frame(3, "prop", _bufs(sizes),
                           serialized=serialized)
    f = framing.Frame(**{**f.__dict__, "trace_id": trace_id})
    parsed, _ = framing.parse_header(framing.header_bytes(f))
    assert parsed.trace_id == trace_id
    assert framing.decode(framing.encode(f)).trace_id == trace_id
    assert f.reply([np.zeros(1, np.uint8)]).trace_id == trace_id
    assert f.reply_chunk([np.zeros(1, np.uint8)],
                         seq=1).trace_id == trace_id


# ---------------------------------------------------------------------------
# span trees under random traffic + window pressure
# ---------------------------------------------------------------------------

@given(n_calls=st.integers(1, 8),
       window_msgs=st.integers(1, 4),
       sizes=st.lists(st.integers(1, 4096), min_size=1, max_size=5),
       data=st.data())
@settings(max_examples=30, deadline=None)
def test_span_invariants_random_unary_traffic(n_calls, window_msgs,
                                              sizes, data):
    tracer = rpc.Tracer()
    fab = rpc.RpcFabric(rpc.make_transport("simulated", 3,
                                           network="eth40g"),
                        window_msgs=window_msgs, tracer=tracer)
    for ep in (1, 2):
        fab.add_server(ep).register("echo", lambda bufs: bufs)
    for i in range(n_calls):
        dst = data.draw(st.sampled_from((1, 2)))
        fab.channel(0, dst).call("echo", _bufs(sizes, seed=i))
    fab.flush()
    roots = tracer.calls()
    assert len(roots) == n_calls
    ids = [r.trace_id for r in roots]
    assert len(set(ids)) == n_calls          # ids are unique per call
    for root in roots:
        _check_tree(root)
    # live tracking state fully reclaimed
    assert not tracer._by_call and not tracer._by_trace


@given(n_chunks=st.integers(1, 4), window_msgs=st.integers(1, 3),
       fault_seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_trace_survives_faulted_retried_streams(n_chunks, window_msgs,
                                                fault_seed):
    """A server-stream under a random transient fault schedule keeps
    ONE trace id across every retry attempt, and the closed tree still
    satisfies nesting + partition."""
    tracer = rpc.Tracer()
    inner = rpc.make_transport("simulated", 2, network="eth40g")
    transport = rpc.make_transport("fault", inner=inner,
                                   seed=fault_seed, fault_rate=0.4,
                                   max_faults=2)
    fab = rpc.RpcFabric(
        transport, window_msgs=window_msgs, tracer=tracer,
        client_interceptors=[rpc.RetryInterceptor(max_attempts=6,
                                                  backoff_s=1e-4)])

    def stream(bufs):
        return ([np.full(8, i, np.uint8)] for i in range(n_chunks))

    fab.add_server(1).register_server_stream("stream", stream)
    h = fab.channel(0, 1).server_stream("stream", _bufs([256]))
    fab.flush()
    # a fault AFTER the first delivered chunk fails the call (stream
    # retry only applies at zero chunks) — the tree invariants must
    # hold either way
    assert h.done
    (root,) = tracer.calls()
    _check_tree(root)
    attempts = root.attempt_spans()
    assert len(attempts) == root.attrs["attempts"]
    # every attempt (incl. re-issues) carries the root's trace id
    assert {a.trace_id for a in attempts} == {root.trace_id}
    if h.error is None:
        assert root.attrs["outcome"] == "stream_end"
    else:
        assert root.attrs["outcome"] == "error"
    if len(attempts) > 1:
        # retries happened: backoff phases separate the attempts
        backoffs = [s for s in root.phase_spans()
                    if s.name == "backoff"]
        assert len(backoffs) == len(attempts) - 1


@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_trace_id_survives_shard_failover(seed):
    """A call rejected by one shard and re-issued on the next keeps
    its trace id; the re-route is visible as the new attempt's dst."""
    from repro.serve.engine import SERVE_SERVICE, ShardedServeStub
    from repro.serve.engine import _i32_buf, decode_generate_request
    cluster = rpc.ClusterSpec(endpoints=(
        rpc.EndpointSpec("ps0", job="ps", admission_limit=1),
        rpc.EndpointSpec("ps1", job="ps"),
        rpc.EndpointSpec("worker0")))
    tracer = rpc.Tracer()
    metrics = rpc.MetricsInterceptor()
    fab = rpc.RpcFabric(
        rpc.make_transport("cluster", cluster=cluster),
        client_interceptors=[metrics],
        server_interceptors=[metrics, rpc.AdmissionInterceptor(
            limits=cluster.admission_limits(), metrics=metrics)],
        tracer=tracer)

    def handlers(name):
        def generate(bufs):
            prompts, mnt = decode_generate_request(bufs)
            return [_i32_buf([prompts.shape[0], max(mnt, 1)]),
                    _i32_buf(np.full((prompts.shape[0], max(mnt, 1)),
                                     int(name[-1]), np.int32))]
        return {"generate": generate, "generate_stream": generate}

    for name in ("ps0", "ps1"):
        fab.add_server(name).add_service(SERVE_SERVICE, handlers(name))
    stub = ShardedServeStub(fab, "worker0", ("ps0", "ps1"))
    prompts = np.random.default_rng(seed).integers(
        0, 100, (1, 4), dtype=np.int32)
    calls = [stub.generate(prompts, 1) for _ in range(3)]
    fab.flush()
    for c in calls:
        assert c.error is None
    assert stub._failover.failovers >= 1
    roots = tracer.calls()
    assert len(roots) == 3
    failed_over = [r for r in roots if len(r.attempt_spans()) > 1]
    assert failed_over
    for root in failed_over:
        dsts = [a.attrs["dst"] for a in root.attempt_spans()]
        assert dsts[0] == "ps0" and dsts[-1] == "ps1"   # the re-route
    for root in roots:
        _check_tree(root)
