"""Fault-tolerance behaviour: failure injection -> recovery from
checkpoint; straggler watchdog; deterministic replay equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config, get_shape
from repro.data.pipeline import DataConfig
from repro.parallel import NO_MESH
from repro.train.trainer import Trainer, TrainerConfig


def _shape():
    return dataclasses.replace(get_shape("train_4k"), seq_len=16,
                               global_batch=2)


def _mk_trainer(tmp_path, total=8, fault_hook=None, **tkw):
    cfg = get_reduced_config("qwen3-8b", n_layers=2)
    tcfg = TrainerConfig(total_steps=total, ckpt_dir=str(tmp_path),
                         ckpt_every=2, log_every=0, **tkw)
    return Trainer(NO_MESH, cfg, _shape(), tcfg, DataConfig(seed=5),
                   fault_hook=fault_hook)


def test_failure_recovery_resumes_from_checkpoint(tmp_path):
    boom = {"armed": True}

    def fault(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    tr = _mk_trainer(tmp_path, total=8, fault_hook=fault,
                     max_step_retries=0)
    tr.train()
    steps = [r.step for r in tr.history]
    assert 5 in steps and 7 in steps
    # step 5 failed once, was re-run after resume from the step-4 ckpt
    assert steps.count(5) >= 1
    assert len(tr.history) >= 8


def test_retry_then_success(tmp_path):
    fails = {"left": 2}

    def fault(step):
        if step == 3 and fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("transient failure")

    tr = _mk_trainer(tmp_path, total=5, fault_hook=fault,
                     max_step_retries=3)
    tr.train()
    rec = [r for r in tr.history if r.step == 3][0]
    assert rec.retried == 2


def test_deterministic_replay_same_loss(tmp_path):
    """Crash+resume must land on the same losses as an uninterrupted run
    (deterministic step-indexed data + checkpointed state)."""
    tr1 = _mk_trainer(tmp_path / "a", total=6)
    tr1.train()
    ref_losses = {r.step: r.loss for r in tr1.history}

    boom = {"armed": True}

    def fault(step):
        if step == 4 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("crash")

    tr2 = _mk_trainer(tmp_path / "b", total=6, fault_hook=fault,
                      max_step_retries=0)
    tr2.train()
    got = {}
    for r in tr2.history:   # last occurrence wins (post-resume rerun)
        got[r.step] = r.loss
    for s in range(6):
        assert got[s] == pytest.approx(ref_losses[s], rel=1e-4), s


def test_trainer_configs_not_shared_between_instances():
    """Bugfix: ``tcfg: TrainerConfig = TrainerConfig()`` in the
    signature was evaluated once at class definition — every Trainer
    built without explicit configs shared (and mutated) the SAME
    instance. Defaults are now constructed per instance."""
    cfg = get_reduced_config("qwen3-8b", n_layers=2)
    t1 = Trainer(NO_MESH, cfg, _shape())
    t2 = Trainer(NO_MESH, cfg, _shape())
    assert t1.tcfg is not t2.tcfg
    assert t1.dcfg is not t2.dcfg
    t1.tcfg.total_steps = 999     # DataConfig is frozen; TrainerConfig
    assert t2.tcfg.total_steps == TrainerConfig().total_steps
    assert t2.dcfg == DataConfig()
    # an explicit config is still taken as-is, not copied
    tcfg = TrainerConfig(total_steps=7)
    assert Trainer(NO_MESH, cfg, _shape(), tcfg).tcfg is tcfg


def test_resume_preserves_checkpoint_extra(tmp_path):
    """Bugfix: resume_or_init unpacked ``(tree, extra)`` from restore
    and dropped ``extra`` on the floor — a resume->save cycle erased
    whatever metadata the launcher had recorded. It now survives on
    ``trainer.resume_extra`` and is written back with every save."""
    from repro.checkpoint import checkpoint as ckpt_lib

    tr = _mk_trainer(tmp_path, total=3)
    params, opt, _ = tr.init_state(0)
    ckpt_lib.save(str(tmp_path), 1, (params, opt),
                  extra={"run_id": "r-42", "cursor": 17})
    tr2 = _mk_trainer(tmp_path, total=3)
    _, _, start = tr2.resume_or_init()
    assert start == 1
    assert tr2.resume_extra == {"run_id": "r-42", "cursor": 17}
    tr2.train()
    last = ckpt_lib.latest_step(str(tmp_path))
    restored, extra = ckpt_lib.restore(str(tmp_path), last, (params, opt))
    assert extra == {"run_id": "r-42", "cursor": 17}


def test_programming_errors_propagate_not_retried(tmp_path):
    """Bugfix: the retry loop caught blanket ``Exception``, so a
    TypeError/ValueError (a bug, not a node failure) was retried and
    then 'recovered' from the checkpoint into the same bug. Only the
    documented STEP_FAULTS boundary is absorbed now."""
    def bug(step):
        if step == 2:
            raise ValueError("programming error, not a node failure")

    tr = _mk_trainer(tmp_path, total=4, fault_hook=bug,
                     max_step_retries=3)
    with pytest.raises(ValueError, match="programming error"):
        tr.train()
    # RuntimeError (the node-failure path) is still absorbed
    fails = {"left": 1}

    def node_fault(step):
        if step == 2 and fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("injected node failure")

    tr2 = _mk_trainer(tmp_path / "b", total=4, fault_hook=node_fault,
                      max_step_retries=3)
    tr2.train()
    assert [r for r in tr2.history if r.step == 2][0].retried == 1


def test_straggler_watchdog(tmp_path):
    import time

    slow = {3, 4, 5}

    def fault(step):
        if step in slow:
            time.sleep(0.5)

    tr = _mk_trainer(tmp_path, total=7, fault_hook=fault,
                     straggler_factor=2.0, straggler_patience=2)
    tr.train()
    assert tr.straggler_events, "watchdog should flag slow steps"
    assert set(tr.straggler_events) <= slow
