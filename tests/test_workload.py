"""Workload tier unit + property tests: seeded arrival processes and
heavy-tailed length samplers (determinism, bounds, distribution
sanity), exact trace JSON round-trips, correlated burst-loss windows
on the fault transport, the open-loop driver's pacing over the bounded
flush, and the SLO report fold. The full-stack acceptance scenario
lives in test_workload_e2e.py."""
import json

import numpy as np
import pytest

from _hypothesis_support import given, settings, st
from repro import rpc
from repro.workload import (ARRIVALS, SIZE_CATEGORIES, SyntheticEngine,
                            Trace, TraceEvent, build_slo_report,
                            bursty_arrivals, correlated_burst_windows,
                            diurnal_arrivals, fixed_lengths,
                            format_slo_table, lognormal_lengths,
                            make_arrivals, make_lengths,
                            materialize_prompts, poisson_arrivals,
                            serve_workload, synthesize_trace,
                            zipf_lengths)

# ---------------------------------------------------------------------------
# arrivals
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(ARRIVALS))
def test_arrivals_sorted_bounded_deterministic(kind):
    a = make_arrivals(kind, 50.0, 2.0, seed=3)
    b = make_arrivals(kind, 50.0, 2.0, seed=3)
    np.testing.assert_array_equal(a, b)       # pure function of seed
    assert np.all(np.diff(a) >= 0)            # sorted
    assert np.all((a >= 0) & (a < 2.0))       # within the horizon
    c = make_arrivals(kind, 50.0, 2.0, seed=4)
    assert len(c) == 0 or len(a) == 0 or not np.array_equal(a, c)


def test_poisson_rate_matches():
    # 2000 expected events: the sample mean rate lands within 10%
    a = poisson_arrivals(200.0, 10.0, seed=0)
    assert abs(len(a) / 10.0 - 200.0) < 20.0


def test_bursty_is_burstier_than_poisson():
    # index of dispersion of per-bin counts: ~1 for Poisson, > 1 for
    # the on-off modulated process (that is what "bursty" means)
    def dispersion(times, duration, bins=50):
        counts, _ = np.histogram(times, bins=bins,
                                 range=(0.0, duration))
        return counts.var() / max(counts.mean(), 1e-9)

    p = poisson_arrivals(100.0, 20.0, seed=1)
    b = bursty_arrivals(100.0, 20.0, seed=1, burst_factor=6.0,
                        idle_factor=0.1)
    assert dispersion(b, 20.0) > 2.0 * dispersion(p, 20.0)


def test_diurnal_follows_the_rate_curve():
    # arrivals in the peak half-period outnumber the trough's
    a = diurnal_arrivals(100.0, 10.0, seed=2, period_s=10.0,
                         depth=0.9)
    peak = np.sum(a < 5.0)       # sin >= 0 half
    trough = np.sum(a >= 5.0)    # sin <= 0 half
    assert peak > 1.5 * trough


def test_make_arrivals_unknown_kind():
    with pytest.raises(ValueError, match="unknown arrival process"):
        make_arrivals("weibull", 1.0, 1.0)


@given(st.integers(min_value=0, max_value=2**31),
       st.floats(min_value=0.5, max_value=200.0),
       st.floats(min_value=0.1, max_value=5.0))
@settings(max_examples=25, deadline=None)
def test_poisson_arrivals_properties(seed, rate, duration):
    a = poisson_arrivals(rate, duration, seed=seed)
    b = poisson_arrivals(rate, duration, seed=seed)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0)
    assert np.all((a >= 0) & (a < duration))


# ---------------------------------------------------------------------------
# lengths
# ---------------------------------------------------------------------------


def test_length_samplers_bounds_and_determinism():
    for fn, kw in ((lognormal_lengths, dict(lo=2, hi=64)),
                   (zipf_lengths, dict(lo=2, hi=64))):
        a = fn(500, seed=5, **kw)
        np.testing.assert_array_equal(a, fn(500, seed=5, **kw))
        assert a.dtype == np.int64
        assert a.min() >= 2 and a.max() <= 64


def test_zipf_is_heavy_tailed():
    a = zipf_lengths(5000, seed=0, alpha=1.2, lo=1, hi=128)
    # mass concentrates at short lengths but the tail is populated
    assert np.mean(a <= 4) > 0.4
    assert a.max() > 64


def test_fixed_lengths_and_size_categories():
    np.testing.assert_array_equal(fixed_lengths(3, value=9),
                                  np.full(3, 9))
    for cat, value in SIZE_CATEGORIES.items():
        np.testing.assert_array_equal(make_lengths(cat, 4),
                                      np.full(4, value))
    with pytest.raises(ValueError, match="unknown length sampler"):
        make_lengths("pareto", 4)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


def test_trace_round_trip_exact(tmp_path):
    tr = synthesize_trace("poisson", 50.0, 1.0, seed=11,
                          prompt_kind="zipf")
    correlated_burst_windows(tr, n_windows=2, width_s=0.1,
                             link=(1, 0))
    path = tmp_path / "t.json"
    tr.save(str(path))
    tr2 = Trace.load(str(path))
    assert [e.to_row() for e in tr2.events] \
        == [e.to_row() for e in tr.events]   # float64-exact
    assert tr2.fault_windows == tr.fault_windows
    assert tr2.seed == tr.seed and tr2.meta == tr.meta


def test_trace_schema_gate():
    doc = json.loads(synthesize_trace("poisson", 5.0, 0.5).to_json())
    doc["schema"] = 99
    with pytest.raises(ValueError, match="schema 99"):
        Trace.from_json(json.dumps(doc))


def test_trace_orders_events_and_rejects_duplicate_ids():
    ev = [TraceEvent(id=1, t_s=0.5, prompt_len=4, max_new_tokens=2),
          TraceEvent(id=0, t_s=0.1, prompt_len=4, max_new_tokens=2)]
    tr = Trace(events=ev)
    assert [e.id for e in tr.events] == [0, 1]
    with pytest.raises(AssertionError, match="duplicate"):
        Trace(events=[ev[0], ev[0]])


def test_synthesize_trace_deterministic():
    a = synthesize_trace("bursty", 30.0, 1.5, seed=8)
    b = synthesize_trace("bursty", 30.0, 1.5, seed=8)
    assert [e.to_row() for e in a.events] \
        == [e.to_row() for e in b.events]
    c = synthesize_trace("bursty", 30.0, 1.5, seed=9)
    assert [e.to_row() for e in a.events] \
        != [e.to_row() for e in c.events]


@given(st.integers(min_value=0, max_value=2**31))
@settings(max_examples=20, deadline=None)
def test_trace_json_round_trip_property(seed):
    tr = synthesize_trace("poisson", 20.0, 0.5, seed=seed)
    tr2 = Trace.from_json(tr.to_json())
    assert [e.to_row() for e in tr2.events] \
        == [e.to_row() for e in tr.events]


def test_materialize_prompts_deterministic_per_event():
    ev = TraceEvent(id=3, t_s=0.0, prompt_len=7, max_new_tokens=2)
    a = materialize_prompts(5, ev)
    np.testing.assert_array_equal(a, materialize_prompts(5, ev))
    assert a.shape == (1, 7) and a.dtype == np.int32
    other = TraceEvent(id=4, t_s=0.0, prompt_len=7, max_new_tokens=2)
    assert not np.array_equal(a, materialize_prompts(5, other))


# ---------------------------------------------------------------------------
# correlated burst-loss windows (FaultInjectionTransport)
# ---------------------------------------------------------------------------


def _cluster_fault(burst_windows, n=3):
    inner = rpc.make_transport(
        "cluster", cluster=rpc.homogeneous(n, "eth40g"))
    return rpc.make_transport("fault", inner=inner,
                              burst_windows=burst_windows)


def test_burst_window_drops_only_inside_the_window():
    t = _cluster_fault([(1.0, 2.0, None)])
    fab = rpc.RpcFabric(t)
    fab.add_server(0).register("echo", lambda bufs: bufs)
    ch = fab.channel(1, 0)
    buf = [np.zeros(64, dtype=np.uint8)]

    call = ch.call("echo", buf)
    fab.flush()
    assert call.done and call.error is None       # before the window
    assert t.burst_faults_injected == 0

    t.clock_s = 1.5                               # inside the window
    call = ch.call("echo", buf)
    fab.flush()
    assert call.error is not None
    assert t.burst_faults_injected >= 1
    assert t.faults_injected >= t.burst_faults_injected

    t.clock_s = 5.0                               # after the window
    call = ch.call("echo", buf)
    fab.flush()
    assert call.done and call.error is None


def test_burst_window_link_restriction_resolves_names():
    # window only on worker0 -> ps0; the other worker sails through
    cluster = rpc.ps_worker_cluster(1, 2)
    inner = rpc.make_transport("cluster", cluster=cluster)
    t = rpc.make_transport(
        "fault", inner=inner,
        burst_windows=[(0.0, 100.0, ("worker0", "ps0"))])
    ps0 = cluster.job_endpoints("ps")[0]
    w0, w1 = cluster.job_endpoints("worker")
    assert t.burst_windows[0][2] == (inner.resolve(w0),
                                     inner.resolve(ps0))
    fab = rpc.RpcFabric(t)
    fab.add_server(ps0).register("echo", lambda bufs: bufs)
    buf = [np.zeros(64, dtype=np.uint8)]
    bad = fab.channel(w0, ps0).call("echo", buf)
    good = fab.channel(w1, ps0).call("echo", buf)
    fab.flush()
    assert bad.error is not None and t.burst_faults_injected >= 1
    assert good.done and good.error is None


def test_burst_window_requires_modeled_inner():
    inner = rpc.make_transport("loopback", 2)
    assert not inner.modeled
    with pytest.raises(AssertionError, match="modeled"):
        rpc.make_transport("fault", inner=inner,
                           burst_windows=[(0.0, 1.0)])


def test_burst_windows_bypass_max_faults():
    # max_faults=0 silences the i.i.d. schedule; windows still drop
    t = _cluster_fault(None)
    t2 = rpc.make_transport(
        "fault",
        inner=rpc.make_transport("cluster",
                                 cluster=rpc.homogeneous(2, "eth40g")),
        fault_rate=1.0, max_faults=0,
        burst_windows=[(0.0, 1e9, None)])
    fab = rpc.RpcFabric(t2)
    fab.add_server(0).register("echo", lambda bufs: bufs)
    call = fab.channel(1, 0).call("echo",
                                  [np.zeros(8, dtype=np.uint8)])
    fab.flush()
    assert call.error is not None
    assert t2.burst_faults_injected >= 1


def test_correlated_burst_windows_attach_to_trace():
    tr = synthesize_trace("poisson", 40.0, 2.0, seed=1)
    wins = correlated_burst_windows(tr, n_windows=3, width_s=0.25)
    assert wins == tr.fault_windows and len(wins) == 3
    for t0, t1, link in wins:
        assert 0.0 <= t0 < t1 <= tr.duration_s + 0.25 + 1e-9
        assert abs((t1 - t0) - 0.25) < 1e-12 and link is None
    # seeded off the trace seed: same trace -> same windows
    tr2 = synthesize_trace("poisson", 40.0, 2.0, seed=1)
    assert correlated_burst_windows(tr2, n_windows=3,
                                    width_s=0.25) == wins


# ---------------------------------------------------------------------------
# bounded flush (the driver's pacing hook)
# ---------------------------------------------------------------------------


def test_flush_until_s_leaves_pending_work():
    t = rpc.make_transport("cluster",
                           cluster=rpc.homogeneous(2, "eth40g"))
    fab = rpc.RpcFabric(t)
    fab.add_server(0).register("echo", lambda bufs: bufs)
    ch = fab.channel(1, 0)
    call = ch.call("echo", [np.zeros(1 << 20, dtype=np.uint8)])
    fab.flush(until_s=0.0)          # bound at/before now: no progress
    assert not call.done
    fab.flush()                     # unbounded drains it
    assert call.done and call.error is None


def test_flush_until_s_monotone_and_resumable():
    t = rpc.make_transport("cluster",
                           cluster=rpc.homogeneous(2, "eth40g"))
    fab = rpc.RpcFabric(t)
    fab.add_server(0).register("echo", lambda bufs: bufs)
    ch = fab.channel(1, 0)
    calls = [ch.call("echo", [np.zeros(1 << 18, dtype=np.uint8)])
             for _ in range(8)]
    t0 = fab.now()
    fab.flush(until_s=t0)            # bound at now: zero progress
    assert fab.now() == t0 and not any(c.done for c in calls)
    fab.flush(until_s=t0 + 1e-9)     # clock only ever moves forward
    mid = fab.now()
    assert mid >= t0
    fab.flush()                      # resuming drains everything
    assert fab.now() >= mid
    assert all(c.done and c.error is None for c in calls)


def test_driver_pacing_fires_events_at_their_arrival_times():
    # two events 1s apart on an otherwise-idle fabric: the driver must
    # jump the modeled clock across the gap, so the second submit
    # happens at (not before) its scheduled arrival
    from repro.workload.driver import run_trace
    from repro.serve.engine import ShardedServeStub, bind_scheduler
    from repro.serve.scheduler import ServeScheduler

    cluster = rpc.ps_worker_cluster(1, 1)
    fab = rpc.RpcFabric(rpc.make_transport("cluster", cluster=cluster))
    eng = SyntheticEngine()
    ps0 = cluster.job_endpoints("ps")[0]
    w0 = cluster.job_endpoints("worker")[0]
    bind_scheduler(fab.add_server(ps0), ServeScheduler(eng))
    stubs = {w0: ShardedServeStub(fab, w0, [ps0])}
    tr = Trace(events=[
        TraceEvent(id=0, t_s=0.0, prompt_len=4, max_new_tokens=2),
        TraceEvent(id=1, t_s=1.0, prompt_len=4, max_new_tokens=2)])
    rec = run_trace(tr, fab, stubs)
    r0, r1 = rec.records[0], rec.records[1]
    assert r0["outcome"] == "ok" and r1["outcome"] == "ok"
    assert r0["submit_s"] == pytest.approx(0.0, abs=1e-6)
    # the first request completed long before the second arrived …
    assert r0["end_s"] < 1.0
    # … and the second was *not* submitted early to fill the idle gap
    assert r1["submit_s"] == pytest.approx(1.0, abs=1e-6)
    assert r1["end_s"] > 1.0
    # the recorder uninstalls itself after the run
    assert all(not isinstance(ic, type(rec))
               for ic in fab.client_interceptors)


# ---------------------------------------------------------------------------
# SLO report
# ---------------------------------------------------------------------------


def _rec(eid, arrival, first, end, *, chunks=4, ok=True,
         outcome="ok"):
    return {"id": eid, "arrival_s": arrival, "submit_s": arrival,
            "first_chunk_s": first, "end_s": end, "chunks": chunks,
            "attempts": 1, "ok": ok, "outcome": outcome}


def test_slo_report_math():
    records = [
        _rec(0, 0.0, 0.010, 0.040),                     # in SLO
        _rec(1, 0.1, 0.200, 0.400),                     # misses 0.25s
        _rec(2, 0.2, None, 0.300, chunks=0),            # unary-ish
        _rec(3, 0.3, None, None, ok=False,
             outcome="deadline_exceeded"),
        _rec(4, 0.4, None, None, ok=False, outcome="error"),
    ]
    rep = build_slo_report(records, span_s=1.0, deadline_s=0.25)
    assert rep.offered == 5
    assert rep.completed_ok == 3
    assert rep.errors == 1 and rep.deadline_exceeded == 1
    # goodput counts ok AND within deadline: events 0 and 2
    assert rep.goodput_rps == pytest.approx(2.0)
    assert rep.offered_rps == pytest.approx(5.0)
    assert rep.slo_attainment == pytest.approx(2 / 5)
    # ttft: event 0 -> 0.010, event 1 -> 0.100, event 2 -> 0.100 (end)
    assert rep.ttft["n"] == 3
    assert rep.ttft["p50"] == pytest.approx(0.1, abs=1e-9)
    # per-token only from streams with >= 2 chunks: events 0, 1
    assert rep.per_token["n"] == 2
    assert rep.per_token["p999"] >= rep.per_token["p50"]
    table = format_slo_table(rep)
    assert "goodput" in table and "p999" in table
    assert "deadline_exceeded 1" in table


def test_slo_report_empty():
    rep = build_slo_report([], span_s=1.0)
    assert rep.offered == 0 and rep.slo_attainment == 0.0
    assert rep.ttft == {"n": 0}
    assert "(no samples)" in format_slo_table(rep)


# ---------------------------------------------------------------------------
# driver (small runs; the acceptance scenario is test_workload_e2e)
# ---------------------------------------------------------------------------


def test_serve_workload_all_tokens_correct():
    tr = synthesize_trace("poisson", 25.0, 1.0, seed=21,
                          prompt_kind="lognormal",
                          prompt_kw={"lo": 2, "hi": 32})
    run = serve_workload(tr, n_ps=1, n_workers=2, max_new_tokens=3)
    assert run.report.completed_ok == len(tr)
    assert run.report.errors == 0
    by_id = {e.id: e for e in tr.events}
    for rec in run.records:
        ev = by_id[rec["id"]]
        assert rec["outcome"] == "ok"
        assert rec["chunks"] == ev.max_new_tokens
        assert rec["end_s"] >= ev.t_s            # causality
        assert rec["first_chunk_s"] <= rec["end_s"]


def test_serve_workload_sjf_policy_reaches_schedulers():
    tr = synthesize_trace("poisson", 10.0, 0.5, seed=2)
    run = serve_workload(tr, n_ps=2, n_workers=1,
                        sched_policy="sjf", starvation_age_s=1.0)
    for sched in run.schedulers.values():
        assert sched.policy == "sjf"
        assert sched.stats()["policy"] == "sjf"


def test_serve_workload_rejects_oversized_trace():
    tr = Trace(events=[TraceEvent(id=0, t_s=0.0, prompt_len=100,
                                  max_new_tokens=8)])
    with pytest.raises(ValueError, match="max_seq"):
        serve_workload(tr, max_seq=64)


def test_serve_workload_needs_ps_and_workers():
    tr = synthesize_trace("poisson", 5.0, 0.5, seed=0)
    with pytest.raises(ValueError, match="worker"):
        serve_workload(tr, cluster=rpc.homogeneous(2, "eth40g"))


def test_synthetic_engine_expected_tokens():
    eng = SyntheticEngine()
    prompts = np.arange(12, dtype=np.int32).reshape(1, 12)
    exp = SyntheticEngine.expected_tokens(prompts, 4)
    base = int(prompts.sum()) % 997
    np.testing.assert_array_equal(exp, base + 7 * np.arange(4))

    class _Req:
        pass
    req = _Req()
    req.prompts, req.rows, req.tokens = prompts, 1, []
    np.testing.assert_array_equal(eng.scheduler_prefill(req),
                                  np.full(1, exp[0]))
