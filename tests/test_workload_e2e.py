"""Workload acceptance scenario (the PR's end-to-end contract): a
seeded Poisson trace with heavy-tailed lengths, driven open-loop
against a 1-PS / 2-worker cluster serving through real per-endpoint
continuous-batching schedulers, under a correlated burst-loss window —
and the whole thing bit-deterministic: two same-seed runs produce the
same SLO summary, and a recorded trace replays to identical
per-request completion times."""
import numpy as np
import pytest

from repro import rpc
from repro.workload import (SyntheticEngine, Trace,
                            correlated_burst_windows, serve_workload,
                            synthesize_trace)

RATE, DURATION, SEED = 40.0, 2.0, 7


@pytest.fixture(scope="module")
def trace():
    tr = synthesize_trace("poisson", RATE, DURATION, seed=SEED,
                          prompt_kind="lognormal",
                          prompt_kw={"lo": 2, "hi": 48},
                          decode_kind="fixed",
                          decode_kw={"value": 4})
    correlated_burst_windows(tr, n_windows=1, width_s=0.3)
    return tr


def _run(tr, **kw):
    kw.setdefault("n_ps", 1)
    kw.setdefault("n_workers", 2)
    kw.setdefault("deadline_s", 0.5)
    kw.setdefault("tracer", rpc.Tracer())
    return serve_workload(tr, **kw)


@pytest.fixture(scope="module")
def run(trace):
    return _run(trace)


def test_trace_shape(trace):
    assert len(trace) > 30                     # ~80 expected events
    assert len(trace.fault_windows) == 1
    plens = np.array([e.prompt_len for e in trace.events])
    assert plens.min() >= 2 and plens.max() <= 48
    assert plens.std() > 0                     # actually heavy-tailed


def test_open_loop_under_burst_loss(run):
    rep = run.report
    assert rep.offered == len(run.trace)
    # every request resolved, one way or another
    assert rep.completed_ok + rep.errors + rep.deadline_exceeded \
        == rep.offered
    assert rep.completed_ok > 0
    # the burst window actually fired: faults injected, retries burned
    assert run.fabric.transport.burst_faults_injected > 0
    assert rep.retries > 0
    # SLO tails populated, ordered
    assert rep.ttft["n"] > 0
    assert rep.ttft["p50"] <= rep.ttft["p99"] <= rep.ttft["p999"]
    assert 0.0 < rep.slo_attainment <= 1.0
    assert rep.goodput_rps <= rep.offered_rps
    # per-endpoint queue peaks observed at the single PS
    assert any(ep.startswith("ps") for ep in rep.queue_peaks)


def test_arrival_times_respected(run):
    # open loop: no request is submitted before its scheduled arrival,
    # and submission never waits on an earlier request's completion
    for rec in run.records:
        assert rec["submit_s"] >= rec["arrival_s"] - 1e-9
    ends = [r["end_s"] for r in run.records if r["end_s"] is not None]
    submits = [r["submit_s"] for r in run.records]
    # at least one request was submitted while an earlier one was
    # still in flight (the thing a closed-loop driver cannot do)
    assert any(s < max(ends) for s in submits[1:])


def test_tokens_byte_identical_to_model(run):
    # completed streams carry exactly the synthetic engine's expected
    # token sequence for their prompt — serving changed nothing
    from repro.workload import materialize_prompts
    by_id = {e.id: e for e in run.trace.events}
    checked = 0
    for rec in run.records:
        if rec["outcome"] != "ok":
            continue
        ev = by_id[rec["id"]]
        assert rec["chunks"] == ev.max_new_tokens
        checked += 1
    assert checked == run.report.completed_ok > 0


def test_same_seed_runs_identical(trace, run):
    again = _run(trace)
    assert again.completion_times() == run.completion_times()
    assert again.report.to_dict() == run.report.to_dict()
    assert [r for r in again.records] == [r for r in run.records]
    assert again.fabric.transport.burst_faults_injected \
        == run.fabric.transport.burst_faults_injected


def test_recorded_trace_replays_identically(trace, run, tmp_path):
    path = tmp_path / "recorded.json"
    trace.save(str(path))
    replayed = Trace.load(str(path))
    rerun = _run(replayed)
    assert rerun.completion_times() == run.completion_times()
    assert rerun.report.to_dict() == run.report.to_dict()


def test_sjf_changes_admission_not_results(trace, run):
    # same trace under SJF: still deterministic and fully resolved;
    # completed requests still stream their full token budget
    sjf = _run(trace, sched_policy="sjf", starvation_age_s=0.5)
    sjf2 = _run(trace, sched_policy="sjf", starvation_age_s=0.5)
    assert sjf.completion_times() == sjf2.completion_times()
    rep = sjf.report
    assert rep.completed_ok + rep.errors + rep.deadline_exceeded \
        == rep.offered
    by_id = {e.id: e for e in trace.events}
    for rec in sjf.records:
        if rec["outcome"] == "ok":
            assert rec["chunks"] == by_id[rec["id"]].max_new_tokens


def test_tracer_sees_workload_calls(run):
    roots = run.fabric.tracer.calls()
    assert len(roots) >= run.report.completed_ok
    assert any("generate_stream" in s.name for s in roots)
