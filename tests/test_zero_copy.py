"""Zero-copy datapath tier: the shared BufferPool, descriptor framing,
per-mode copy-cost pricing, credit accounting by described bytes, the
--wire-mode CLI surface, and the framing/encode bugfix sweep that rode
along (reply coercion, backend validation on every path, corrupt-header
hardening)."""
import json

import numpy as np
import pytest

from repro import rpc
from repro.configs.tfgrpc_bench import BenchConfig
from repro.core import bench, netmodel
from repro.core.netmodel import NETWORKS
from repro.core.payload import PayloadSpec
from repro.rpc import bufpool, framing


def _bufs(sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 255, s, dtype=np.uint8) for s in sizes]


SIZES = (10, 300, 1024, 7, 128, 4096)


# ---------------------------------------------------------------------------
# wire-mode vocabulary
# ---------------------------------------------------------------------------

def test_wire_modes_pinned_equal():
    """framing and netmodel each define WIRE_MODES (rpc must stay
    importable without pulling the model and vice versa) — pinned
    identical here, like LANE is pinned to the kernel lane."""
    assert framing.WIRE_MODES == netmodel.WIRE_MODES
    assert framing.WIRE_MODES == ("serialized", "scatter_gather",
                                  "zero_copy")


def test_resolve_wire_mode():
    assert framing.resolve_wire_mode() == "scatter_gather"
    assert framing.resolve_wire_mode(serialized=True) == "serialized"
    for wm in framing.WIRE_MODES:
        assert framing.resolve_wire_mode(wire_mode=wm) == wm
    assert framing.resolve_wire_mode(
        serialized=True, wire_mode="serialized") == "serialized"
    with pytest.raises(ValueError, match="conflicts"):
        framing.resolve_wire_mode(serialized=True, wire_mode="zero_copy")
    with pytest.raises(ValueError, match="unknown wire mode"):
        framing.resolve_wire_mode(wire_mode="rdma")


def test_resolved_wire_mode_config():
    assert BenchConfig().resolved_wire_mode == "scatter_gather"
    assert BenchConfig(mode="serialized").resolved_wire_mode \
        == "serialized"
    # explicit wins over the paper's two-valued mode field
    assert BenchConfig(wire_mode="zero_copy").resolved_wire_mode \
        == "zero_copy"


# ---------------------------------------------------------------------------
# BufferPool
# ---------------------------------------------------------------------------

def test_pool_place_read_roundtrip():
    pool = bufpool.BufferPool(pool_id=91, capacity=1 << 16)
    b = _bufs([300])[0]
    off, size = pool.place(b)
    assert size == 300 and off % framing.LANE == 0
    assert np.array_equal(pool.read(off, size), b)


def test_pool_lane_aligned_and_zero_size():
    pool = bufpool.BufferPool(pool_id=92, capacity=1 << 12)
    offs = [pool.place(b)[0] for b in _bufs([1, 0, 127, 129])]
    assert all(o % framing.LANE == 0 for o in offs)
    assert len(set(offs)) == 4    # a zero-size buffer still gets a slot
    assert pool.read(offs[1], 0).size == 0


def test_pool_wraps_and_rejects_oversize():
    pool = bufpool.BufferPool(pool_id=93, capacity=4 * framing.LANE)
    for _ in range(3):
        pool.place(np.zeros(framing.LANE, np.uint8))
    assert pool.wraps == 0
    off, _ = pool.place(np.arange(200, dtype=np.uint8))  # tail too small
    assert off == 0 and pool.wraps == 1
    with pytest.raises(ValueError, match="capacity"):
        pool.place(np.zeros(5 * framing.LANE, np.uint8))
    with pytest.raises(ValueError):
        pool.read(3 * framing.LANE, 2 * framing.LANE)  # out of range


def test_pool_registry():
    p = rpc.get_pool(77)
    assert rpc.get_pool(77) is p and p.pool_id == 77
    assert rpc.get_pool() is rpc.get_pool(0)
    rpc.reset_pools()
    assert rpc.get_pool(77) is not p


def test_pool_owned_placements_pin_their_slots():
    """Bugfix: an owned (in-flight) span must never be recycled. The
    allocator skips live spans when it wraps and raises PoolExhausted
    when no gap fits — the old wrapping bump allocator silently
    overwrote the slot and the receiver's view read torn bytes."""
    pool = bufpool.BufferPool(pool_id=94, capacity=4 * framing.LANE)
    a = np.arange(3 * framing.LANE, dtype=np.uint8) % 251
    off, size = pool.place(a, owner=1)
    assert pool.live_bytes() == 3 * framing.LANE
    view = pool.read(off, size)
    with pytest.raises(bufpool.PoolExhausted, match="in-flight"):
        pool.place(np.zeros(2 * framing.LANE, np.uint8), owner=2)
    assert np.array_equal(view, a)          # survived the failed place
    # wrap AROUND a live span is fine when a gap fits
    off2, _ = pool.place(np.zeros(framing.LANE, np.uint8), owner=2)
    assert off2 == 3 * framing.LANE and np.array_equal(view, a)
    # completion frees the span; the next placement reuses it
    assert pool.release(1) == 3 * framing.LANE
    assert pool.release(1) == 0             # idempotent
    pool.place(np.zeros(2 * framing.LANE, np.uint8), owner=3)
    assert pool.live_bytes() == 3 * framing.LANE
    pool.reset()
    assert pool.live_bytes() == 0


def test_release_call_spans_all_pools():
    rpc.reset_pools()
    a, b = rpc.get_pool(1, capacity=1 << 12), rpc.get_pool(2,
                                                           capacity=1 << 12)
    a.place(np.zeros(100, np.uint8), owner=7)
    b.place(np.zeros(50, np.uint8), owner=7)
    assert rpc.release_call(7) == 2 * framing.LANE
    assert a.live_bytes() == 0 and b.live_bytes() == 0
    rpc.reset_pools()


# ---------------------------------------------------------------------------
# framing: three-mode round trips + the bugfix sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_frame_roundtrip_byte_identical(wire_mode):
    f = framing.make_frame(3, "m", _bufs(SIZES), wire_mode=wire_mode)
    assert f.wire_mode == wire_mode
    g = framing.decode(framing.encode(f))
    assert g.sizes == f.sizes
    for a, b in zip(f.bufs, g.bufs):
        assert np.array_equal(a, b)


def test_zero_copy_wire_is_descriptors_not_bytes():
    f = framing.make_frame(1, "zc", _bufs([1 << 20, 1 << 19]),
                           wire_mode="zero_copy")
    msgs = framing.encode(f)
    wire = sum(int(m.size) for m in msgs)
    assert f.total_bytes == (1 << 20) + (1 << 19)
    assert wire < 1024                 # header + 2 descriptor triples
    g = framing.decode(msgs)
    # decoded bufs are VIEWS into the shared pool region — zero copies
    assert np.shares_memory(g.bufs[0], rpc.get_pool().region)
    assert np.array_equal(g.bufs[0], f.bufs[0])


@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_reply_coerces_bufs(wire_mode):
    """Bugfix: Frame.reply() must coerce handler outputs (lists,
    non-uint8 dtypes, non-contiguous arrays) exactly like make_frame
    does — a handler returning a plain list used to blow up encode."""
    f = framing.make_frame(5, "r", _bufs([64]), wire_mode=wire_mode)
    r = f.reply([[1, 2, 3], np.arange(4, dtype=np.int64).view(np.uint8),
                 np.arange(256, dtype=np.uint8)[::2]])
    assert r.wire_mode == wire_mode    # mode bits survive the reply
    assert all(b.dtype == np.uint8 and b.flags.c_contiguous
               for b in r.bufs)
    g = framing.decode(framing.encode(r))
    assert g.is_reply and g.sizes == (3, 32, 128)
    assert np.array_equal(g.bufs[0], np.array([1, 2, 3], np.uint8))


@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_encode_decode_validate_backend(wire_mode):
    """Bugfix: encode() used to validate ``backend`` only on the
    serialized path — an unknown backend silently fell through on the
    scatter-gather path. Now every path rejects it, decode too."""
    f = framing.make_frame(2, "b", _bufs([32]), wire_mode=wire_mode)
    with pytest.raises(ValueError, match="backend"):
        framing.encode(f, backend="bogus")
    with pytest.raises(ValueError, match="backend"):
        framing.decode(framing.encode(f), backend="bogus")


def test_parse_header_truncated():
    with pytest.raises(framing.FramingError, match="truncated"):
        framing.parse_header(np.zeros(16, dtype=np.uint8))


def test_parse_header_corrupt_n_buffers():
    """Bugfix: a corrupt n_buffers word used to index past the wire
    buffer (IndexError deep in numpy); now a clear framing error."""
    f = framing.make_frame(4, "c", _bufs([8, 8]))
    wire = framing.header_bytes(f).copy()
    wire.view("<u4")[7] = 1 << 30              # the n_buffers word
    with pytest.raises(framing.FramingError, match="n_buffers"):
        framing.parse_header(wire)


# ---------------------------------------------------------------------------
# copy-cost model: closed forms == transports, per mode
# ---------------------------------------------------------------------------

def _spec(sizes):
    return PayloadSpec(sizes=tuple(sizes), scheme="t",
                       categories=("medium",) * len(sizes))


def test_copy_cost_ordering():
    """At large payloads the three tiers must separate: serialized pays
    pack+unpack on every byte, scatter-gather a per-iovec fixed cost,
    zero-copy only registration amortized over pool reuse."""
    spec = _spec([1 << 20] * 8)
    for name, net in NETWORKS.items():
        zc = net.copy_cost(spec, "zero_copy")
        sg = net.copy_cost(spec, "scatter_gather")
        ser = net.copy_cost(spec, "serialized")
        assert zc < ser, name
        assert net.payload_time(spec, mode="zero_copy") \
            < net.payload_time(spec, mode="serialized"), name
        if not name.startswith("tpu"):
            # the paper's NIC-class networks: per-iovec alpha dominates
            # the amortized registration, and per-byte pack/unpack
            # dominates both. The tpu models price serialization near
            # memory bandwidth and sub-us launches, so only the
            # zero-copy-vs-serialized ordering is universal.
            assert zc < sg < ser, name


@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_simulated_fc_matches_closed_form(wire_mode):
    spec = _spec([65536] * 4)
    for name in ("eth40g", "rdma_edr"):
        net = NETWORKS[name]
        fab = rpc.RpcFabric(rpc.SimulatedTransport(8, net))
        rep = rpc.fully_connected_exchange(fab, list(spec.sizes),
                                           wire_mode=wire_mode)
        # 1e-12: bit-exact up to summation order (the transport folds
        # k equal ingress terms by addition, the closed form by k*t)
        assert rep.elapsed_s == pytest.approx(
            net.fc_round_time(spec, 8, mode=wire_mode), rel=1e-12), name


@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_cluster_fc_matches_closed_form(wire_mode):
    cluster = rpc.homogeneous(4, "eth40g")
    fab = rpc.RpcFabric(rpc.make_transport("cluster", cluster=cluster),
                        window_bytes=64 << 20, window_msgs=256)
    sizes = [65536] * 4
    rep = rpc.fully_connected_exchange(fab, sizes, wire_mode=wire_mode)
    assert rep.elapsed_s == rpc.cluster_fc_round_time(cluster, sizes,
                                                      mode=wire_mode)


def test_zero_copy_beats_serialized_at_large_payloads():
    net = NETWORKS["eth40g"]
    sizes = [1 << 20] * 4
    elapsed = {}
    for wm in ("serialized", "zero_copy"):
        fab = rpc.RpcFabric(rpc.SimulatedTransport(4, net))
        elapsed[wm] = rpc.fully_connected_exchange(
            fab, sizes, wire_mode=wm).elapsed_s
    assert elapsed["zero_copy"] < elapsed["serialized"]


# ---------------------------------------------------------------------------
# fabric: byte-identical delivery + credits by described bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wire_mode", framing.WIRE_MODES)
def test_fabric_echo_byte_identical(wire_mode):
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    stub = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1,
                    wire_mode=wire_mode)
    payload = _bufs(SIZES, seed=3)
    out = stub.echo(payload).result()
    assert [b.tolist() for b in out] == [b.tolist() for b in payload]


def test_wire_mode_channels_cached_separately():
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    chans = {wm: fab.channel(0, 1, wire_mode=wm)
             for wm in framing.WIRE_MODES}
    assert len({id(c) for c in chans.values()}) == 3
    assert fab.channel(0, 1) is chans["scatter_gather"]
    assert fab.channel(0, 1, serialized=True) is chans["serialized"]


def test_zero_copy_credits_charged_by_described_bytes():
    """Flow control must price a descriptor frame by the bytes it
    DESCRIBES, not the ~100 wire bytes it ships — otherwise zero-copy
    sidesteps backpressure entirely."""
    f = framing.make_frame(1, "fc", _bufs([600_000]),
                           wire_mode="zero_copy")
    assert f.total_bytes == 600_000
    assert sum(int(m.size) for m in framing.encode(f)) < 1024

    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=1 << 20, window_msgs=4)
    fab.add_server(1).register("tiny",
                               lambda req: [np.zeros(1, np.uint8)])
    ch = fab.channel(0, 1, wire_mode="zero_copy")
    for i in range(6):
        c = ch.call("tiny", _bufs([600_000], seed=i))
        fab.flush()
        assert c.done and c.error is None
    # request credits restored in full after each flight: no leak, and
    # two 600 kB described requests can never be in flight on a 1 MB
    # window even though their wire footprint is tiny
    assert ch.window.bytes_avail == 1 << 20
    assert ch.window.msgs_avail == 4


def test_flight_over_pool_capacity_raises_not_tears():
    """Regression: four 400 kB echo calls in ONE flight through a 1 MiB
    pool. The old wrapping allocator recycled the first calls' live
    slots mid-flight and every reply came back with torn bytes (header
    garbage from later placements). Free-on-complete pins each call's
    spans until its reply lands, so this now fails loudly instead."""
    rpc.reset_pools()
    rpc.get_pool(capacity=1 << 20)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2),
                        window_bytes=16 << 20, window_msgs=64)
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    ch = fab.channel(0, 1, wire_mode="zero_copy")
    for i in range(4):
        ch.call("repro.Conformance/Echo", [np.full(400_000, i, np.uint8)])
    with pytest.raises(bufpool.PoolExhausted, match="pinned"):
        fab.flush()
    rpc.reset_pools()


def test_free_on_complete_recycles_slots():
    """Steady state: sequential zero-copy echoes whose cumulative bytes
    dwarf the pool — completion releases each call's spans, so every
    reply is byte-exact and nothing stays pinned."""
    rpc.reset_pools()
    pool = rpc.get_pool(capacity=2 << 20)
    fab = rpc.RpcFabric(rpc.LoopbackTransport(2))
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    stub = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1, wire_mode="zero_copy")
    for i in range(16):                     # 16 x 700 kB x 2 (req+reply)
        payload = _bufs([700_000], seed=i)
        out = stub.echo(payload).result()
        assert np.array_equal(out[0], payload[0]), f"torn at echo {i}"
    assert pool.live_bytes() == 0           # everything released
    assert pool.releases == 16 and pool.placements == 32
    rpc.reset_pools()


def test_retry_releases_dead_attempt_spans():
    """A faulted attempt's placements are unpinned before the retry
    re-places the frames — repeated retries through a small pool must
    not exhaust it, and the final reply is byte-exact."""
    rpc.reset_pools()
    pool = rpc.get_pool(capacity=2 << 20)
    transport = rpc.FaultInjectionTransport(
        rpc.LoopbackTransport(2), seed=3, fault_rate=0.5, max_faults=12)
    fab = rpc.RpcFabric(transport, client_interceptors=[
        rpc.RetryInterceptor(max_attempts=16)])
    fab.add_server(1).add_service(rpc.CONFORMANCE_SERVICE,
                                  rpc.conformance_handlers())
    stub = fab.stub(rpc.CONFORMANCE_SERVICE, 0, 1, wire_mode="zero_copy")
    for i in range(8):
        payload = _bufs([600_000], seed=100 + i)
        out = stub.echo(payload).result()
        assert np.array_equal(out[0], payload[0])
    assert transport.faults_injected > 0, "no faults fired — vacuous"
    assert pool.live_bytes() == 0
    rpc.reset_pools()


# ---------------------------------------------------------------------------
# bench + CLI surface
# ---------------------------------------------------------------------------

def test_collective_zero_copy_rejected():
    cfg = BenchConfig(benchmark="fully_connected", num_workers=2,
                      transport="collective", wire_mode="zero_copy")
    with pytest.raises(RuntimeError, match="collective"):
        bench.run(cfg)
    # the paper's three benchmarks run the collective datapath too
    cfg = BenchConfig(benchmark="p2p_latency", wire_mode="zero_copy")
    with pytest.raises(RuntimeError, match="collective"):
        bench.run(cfg)


def test_bench_comm_wire_mode_conflicts_with_serialized_mode():
    from repro.launch import bench_comm
    with pytest.raises(SystemExit):
        bench_comm.main(["--mode", "serialized",
                         "--wire-mode", "zero_copy"])


def test_bench_comm_wire_mode_payload_sweep(tmp_path):
    """The acceptance sweep: wire_mode x payload on one table, all
    three modes, zero_copy strictly below serialized at large."""
    from repro.launch import bench_comm
    out = tmp_path / "rows.json"
    bench_comm.main(["--sweep", "wire_mode,payload",
                     "--benchmark", "fully_connected",
                     "--transport", "simulated", "--network", "eth40g",
                     "--num-workers", "3", "--warmup", "0.05",
                     "--duration", "0.2", "--json", str(out)])
    rows = json.loads(out.read_text())["rows"]
    assert len(rows) == 3 * 3
    combos = {(r["wire_mode"], r["payload"]) for r in rows}
    assert combos == {(w, p) for w in framing.WIRE_MODES
                      for p in ("small", "medium", "large")}
    mean = {(r["wire_mode"], r["payload"]): r["mean_us"] for r in rows}
    assert mean[("zero_copy", "large")] < mean[("serialized", "large")]
    assert all(r["value"] > 0 for r in rows)


def test_bench_comm_collective_zero_copy_cell_skipped(capsys):
    from repro.launch import bench_comm
    bench_comm.main(["--sweep", "wire_mode", "--benchmark",
                     "fully_connected", "--transport", "collective",
                     "--num-workers", "2", "--warmup", "0.05",
                     "--duration", "0.2"])
    table = capsys.readouterr().out
    assert "SKIPPED" in table and "zero_copy" in table


def test_baseline_schema3_covers_wire_modes():
    b = bench.collect_baseline(num_workers=2)
    assert b["schema"] == bench.BASELINE_SCHEMA == 3
    assert set(b["wire_modes"]) == set(framing.WIRE_MODES)
    fams = {"p2p_latency", "p2p_bandwidth", "ps_throughput",
            "fully_connected", "ring", "incast",
            "allreduce_ring", "allreduce_tree", "allreduce_rsag",
            "train_step_ps", "train_step_allreduce"}
    for wm, entry in b["wire_modes"].items():
        assert set(entry) == fams, wm
        assert all(v["round_time_s"] > 0 for v in entry.values())
    # the legacy families block is schema-1-compatible and must match
    # the scatter_gather tier (the seed's non_serialized default)
    sg = b["wire_modes"]["scatter_gather"]
    for fam in fams:
        assert b["families"][fam]["round_time_s"] \
            == sg[fam]["round_time_s"], fam
    # schema 3: the committed PS -> allreduce crossover sweep
    cross = b["train_crossover"]
    assert [p["workers"] for p in cross["points"]] \
        == list(bench.CROSSOVER_WORKERS)
    assert cross["allreduce_wins_from"] is not None
    winners = [p["winner"] for p in cross["points"]]
    assert "ps" in winners and "allreduce" in winners
    assert winners[-1] == "allreduce"          # AR holds at scale
    assert not bench.check_baseline(b)         # self-diff is clean
